//! Chaos harness (DESIGN.md "Failure model"): named fault-injection
//! scenarios over the real coordinator stack — task queue, checkpoint DB,
//! DPC2 files, sharded outer executors — each judged by a
//! convergence-equivalence oracle against a fault-free run of the same
//! seeded recipe.
//!
//! Pass criteria per scenario: either the faulted run converges to a
//! **bit-identical** `ModuleStore` (recoverable faults: kills, preemption,
//! lease expiry, stragglers, delayed/reordered publication, executor
//! drop/re-join) or it aborts **loudly** with a structured error
//! (unrecoverable faults: checkpoint corruption). Silent divergence and
//! silent success both fail.
//!
//! Engine-free: the inner phase is simulated by a pure function of
//! `(seed, phase, path, theta)`, so no `make artifacts` is needed and no
//! scenario is skipped.

use dipaco::chaos::corruptor::CorruptMode;
use dipaco::chaos::oracle::{
    run_scenario, run_scenario_vs, run_scenario_vs_tol, ChaosReport, Verdict,
};
use dipaco::chaos::plan::{Fault, FaultPlan};
use dipaco::chaos::sim::SimSpec;
use dipaco::config::DeltaCodec;

fn assert_converged(r: &ChaosReport) {
    assert!(
        matches!(r.verdict, Verdict::ConvergedIdentical),
        "expected bit-identical convergence, got {:?}\nreport: {}",
        r.verdict,
        r.to_json().to_string_pretty()
    );
    assert!(r.is_pass());
    assert_eq!(r.faulted_digest, Some(r.reference_digest));
    assert!(r.unfired.is_empty(), "planned faults never fired: {:?}", r.unfired);
}

fn assert_aborted(r: &ChaosReport, detector_msg: &str) {
    match &r.verdict {
        Verdict::AbortedLoudly { error } => {
            assert!(
                error.contains(detector_msg),
                "abort fired from the wrong detector.\n  want: {detector_msg:?}\n  got:  {error}"
            );
        }
        v => panic!(
            "corruption must abort loudly, got {v:?}\nreport: {}",
            r.to_json().to_string_pretty()
        ),
    }
    assert!(r.is_pass());
    assert_eq!(r.faulted_digest, None, "an aborted run has no final digest");
}

// ---- worker/queue-plane faults: must converge bit-identically ----

#[test]
fn chaos_worker_kill_mid_phase() {
    // Hard worker crashes mid-phase: only lease expiry + reclaim recovers
    // the abandoned tasks.
    let mut spec = SimSpec::new(11);
    spec.lease_ms = 700;
    let plan = FaultPlan::new(vec![
        Fault::KillWorker { phase: 0, path: 1 },
        Fault::KillWorker { phase: 1, path: 2 },
    ]);
    let r = run_scenario("worker-kill", &spec, &plan).unwrap();
    assert_converged(&r);
    assert_eq!(r.fired.len(), 2);
    assert_eq!(r.requeues, 2, "each kill recovers via exactly one redelivery");
    assert_eq!(r.phases_run, 3);
}

#[test]
fn chaos_preemption_graceful() {
    // Graceful preemption: the worker fails its lease, the task requeues
    // immediately (no expiry wait).
    let spec = SimSpec::new(12);
    let plan = FaultPlan::new(vec![
        Fault::Preempt { phase: 0, path: 0 },
        Fault::Preempt { phase: 2, path: 3 },
    ]);
    let r = run_scenario("preemption", &spec, &plan).unwrap();
    assert_converged(&r);
    assert_eq!(r.requeues, 2);
    assert_eq!(r.completed, 12);
}

#[test]
fn chaos_lease_expiry_redelivery() {
    // A worker stalls past its lease; the task is redelivered and the
    // stalled zombie's late writes/retirement must all be rejected or
    // absorbed idempotently.
    let mut spec = SimSpec::new(13);
    spec.lease_ms = 300;
    let plan = FaultPlan::new(vec![Fault::ExpireLease {
        phase: 1,
        path: 0,
        hold_ms: 1500,
    }]);
    let r = run_scenario("lease-expiry", &spec, &plan).unwrap();
    assert_converged(&r);
    assert_eq!(r.requeues, 1, "expiry reclaim redelivers exactly once");
    // 12 tasks retire exactly once each — the zombie's stale complete()
    // must NOT count
    assert_eq!(r.completed, 12);
}

#[test]
fn chaos_straggler_heterogeneous_speeds() {
    // Stragglers within their lease: arrival order changes, results must
    // not (the executor reduces in path-id order at quorum).
    let spec = SimSpec::new(14);
    let plan = FaultPlan::new(vec![
        Fault::Straggle { phase: 0, path: 0, delay_ms: 120 },
        Fault::Straggle { phase: 1, path: 2, delay_ms: 60 },
        Fault::Straggle { phase: 2, path: 1, delay_ms: 180 },
    ]);
    let r = run_scenario("straggler", &spec, &plan).unwrap();
    assert_converged(&r);
    assert_eq!(r.requeues, 0, "stragglers stayed within their leases");
}

#[test]
fn chaos_executor_drop_and_rejoin() {
    // An outer executor drops out for phase 1 and re-joins for phase 2:
    // modules are re-sharded both times, and each module's Nesterov
    // velocity must follow it to its new owner bit-exactly.
    let mut faulted = SimSpec::new(15);
    faulted.executors_per_phase = vec![2, 1, 2];
    let mut reference = SimSpec::new(15);
    reference.executors_per_phase = vec![2];
    let r = run_scenario_vs("executor-rejoin", &faulted, &reference, &FaultPlan::none()).unwrap();
    assert_converged(&r);
    assert_eq!(r.requeues, 0);
    assert_eq!(r.phases_run, 3);
}

#[test]
fn chaos_delayed_publication() {
    // Checkpoints written on time but published late: the online
    // averaging just waits; nothing is lost or double-counted.
    let spec = SimSpec::new(19);
    let plan = FaultPlan::new(vec![
        Fault::DelayPublish { phase: 0, path: 2, delay_ms: 150 },
        Fault::DelayPublish { phase: 2, path: 0, delay_ms: 80 },
    ]);
    let r = run_scenario("delayed-publish", &spec, &plan).unwrap();
    assert_converged(&r);
    assert_eq!(r.requeues, 0);
}

#[test]
fn chaos_reordered_publication() {
    // Adversarial arrival order: path 0's checkpoint is held until path 3
    // has published. f32 accumulation is order-sensitive, so this is the
    // direct probe of the sorted-quorum reduce.
    let spec = SimSpec::new(20);
    let plan = FaultPlan::new(vec![Fault::ReorderPublish {
        phase: 1,
        first: 3,
        then: 0,
    }]);
    let r = run_scenario("reordered-publish", &spec, &plan).unwrap();
    assert_converged(&r);
    assert!(
        r.fired.iter().all(|e| !e.contains("timed out")),
        "reorder resolved by dependency, not by deadline: {:?}",
        r.fired
    );
}

// ---- streaming outer sync: staggered publication, late carry, codecs ----

#[test]
fn chaos_streaming_staggered_f32_matches_whole_path_publication() {
    // Staggered per-module-group publication with the exact f32 codec is
    // pure plumbing: the same contributions reach the same modules and
    // the executor reduces them in canonical order, so the result must be
    // bit-identical to whole-path publication of the same seeded run —
    // even with stragglers shuffling group-row arrival order.
    let mut faulted = SimSpec::new(21);
    faulted.publish_groups = 2;
    let reference = SimSpec::new(21); // whole-path rows, no residual chain
    let plan = FaultPlan::new(vec![
        Fault::Straggle { phase: 0, path: 1, delay_ms: 90 },
        Fault::Straggle { phase: 1, path: 3, delay_ms: 50 },
    ]);
    let r = run_scenario_vs("streaming-staggered-f32", &faulted, &reference, &plan).unwrap();
    assert_converged(&r);
    assert_eq!(r.phases_run, 3);
    assert_eq!(r.requeues, 0, "stragglers stayed within their leases");
}

#[test]
fn chaos_late_straggler_carries_into_next_phase() {
    // A path declared late in phase 1: its modules apply at reduced
    // quorum, its contribution merges into phase 2's accumulation. Both
    // runs share the declaration (the carry is part of the recipe); the
    // faulted run additionally straggles that very path, which must not
    // change a single byte.
    let mut faulted = SimSpec::new(22);
    faulted.declared_late = vec![(1, 2)];
    let mut reference = SimSpec::new(22);
    reference.declared_late = vec![(1, 2)];
    let plan = FaultPlan::new(vec![Fault::Straggle { phase: 1, path: 2, delay_ms: 120 }]);
    let r = run_scenario_vs("late-straggler-carry", &faulted, &reference, &plan).unwrap();
    assert_converged(&r);
    assert_eq!(r.phases_run, 3);
    assert_eq!(r.completed, 12, "the late path's task still completes");
}

#[test]
fn chaos_streaming_int8_bounded_divergence() {
    // Int8-quantized deltas with error feedback vs the exact-f32 run of
    // the same seed: bitwise identity is off the table by construction,
    // but the residual chain keeps the drift bounded — the oracle demands
    // ConvergedBounded within a small tolerance, and a nonzero gap
    // (proof the lossy codec actually engaged).
    let mut faulted = SimSpec::new(23);
    faulted.codec = DeltaCodec::Int8;
    faulted.publish_groups = 2;
    let reference = SimSpec::new(23);
    let r = run_scenario_vs_tol(
        "streaming-int8-bounded",
        &faulted,
        &reference,
        &FaultPlan::none(),
        Some(0.05),
    )
    .unwrap();
    match &r.verdict {
        Verdict::ConvergedBounded { max_abs } => {
            assert!(*max_abs > 0.0, "int8 quantization should move at least one bit");
            assert!(*max_abs <= 0.05, "drift exceeded tolerance: {max_abs}");
        }
        v => panic!(
            "expected bounded convergence, got {v:?}\nreport: {}",
            r.to_json().to_string_pretty()
        ),
    }
    assert!(r.is_pass());
    assert_eq!(r.phases_run, 3);
}

// ---- network plane: TCP section exchange under in-flight faults ----

fn tcp_spec(seed: u64) -> SimSpec {
    let mut spec = SimSpec::new(seed);
    spec.tcp = true;
    spec
}

#[test]
fn chaos_tcp_transport_matches_filesystem_bit_for_bit() {
    // The acceptance gate for the exchange plane: the same seeded recipe
    // run once over TCP loopback and once over the shared filesystem must
    // land the ModuleStore on identical bytes — the transport is pure
    // plumbing, invisible to the math.
    let r = run_scenario_vs(
        "tcp-vs-filesystem",
        &tcp_spec(31),
        &SimSpec::new(31),
        &FaultPlan::none(),
    )
    .unwrap();
    assert_converged(&r);
    assert_eq!(r.phases_run, 3);
}

#[test]
fn chaos_tcp_dropped_frame_retries_to_convergence() {
    // A section frame dropped in flight: the push client retries with
    // backoff and the run still matches the FILESYSTEM reference byte for
    // byte. The retry lives in the transport — the task queue never sees
    // a failure.
    let plan = FaultPlan::new(vec![Fault::NetDrop { phase: 1, path: 2 }]);
    let r = run_scenario_vs("tcp-drop-retry", &tcp_spec(32), &SimSpec::new(32), &plan).unwrap();
    assert_converged(&r);
    assert_eq!(r.requeues, 0, "drop recovers inside the transport, not the queue");
}

#[test]
fn chaos_tcp_duplicated_frame_is_deduped() {
    // A duplicated put frame (retransmit race): the server's idempotency
    // key accepts it once — a double-accumulate would move the digest.
    let plan = FaultPlan::new(vec![Fault::NetDuplicate { phase: 0, path: 1 }]);
    let r = run_scenario_vs("tcp-duplicate", &tcp_spec(33), &SimSpec::new(33), &plan).unwrap();
    assert_converged(&r);
}

#[test]
fn chaos_tcp_truncated_frame_is_nacked_and_resent() {
    // A payload torn in flight: lengths still frame the stream, the
    // fletcher64 trailer fails, the server nacks, the client resends
    // clean bytes. No garbage may reach the accumulators.
    let plan = FaultPlan::new(vec![Fault::NetTruncate { phase: 2, path: 0 }]);
    let r = run_scenario_vs("tcp-truncate", &tcp_spec(34), &SimSpec::new(34), &plan).unwrap();
    assert_converged(&r);
    assert_eq!(r.requeues, 0, "the nack-resend cycle never surfaces to the queue");
}

#[test]
fn chaos_tcp_delayed_frame_arrives_late_but_intact() {
    let plan = FaultPlan::new(vec![Fault::NetDelay {
        phase: 1,
        path: 3,
        delay_ms: 60,
    }]);
    let r = run_scenario_vs("tcp-delay", &tcp_spec(35), &SimSpec::new(35), &plan).unwrap();
    assert_converged(&r);
}

// ---- checkpoint-plane faults: must abort loudly, never average garbage ----

fn corruption_spec(seed: u64) -> SimSpec {
    let mut spec = SimSpec::new(seed);
    // One executor: a corrupt section aborts that executor, and sibling
    // executors of the same phase would otherwise idle on their
    // subscription channel waiting for a phase that is already dead.
    spec.executors_per_phase = vec![1];
    spec
}

#[test]
fn chaos_section_truncation_aborts_loudly() {
    let plan = FaultPlan::new(vec![Fault::Corrupt {
        phase: 0,
        path: 0,
        mode: CorruptMode::TruncatePayload,
    }]);
    let r = run_scenario("truncation", &corruption_spec(16), &plan).unwrap();
    assert_aborted(&r, "truncated payload");
    assert_eq!(r.phases_run, 0, "the corrupted phase must not commit");
}

#[test]
fn chaos_payload_bitflip_aborts_loudly() {
    let plan = FaultPlan::new(vec![Fault::Corrupt {
        phase: 0,
        path: 0,
        mode: CorruptMode::FlipPayloadByte,
    }]);
    let r = run_scenario("bitflip", &corruption_spec(17), &plan).unwrap();
    assert_aborted(&r, "checksum mismatch");
    assert_eq!(r.phases_run, 0);
}

#[test]
fn chaos_directory_corruption_aborts_loudly() {
    let plan = FaultPlan::new(vec![Fault::Corrupt {
        phase: 0,
        path: 0,
        mode: CorruptMode::DamageDirectory,
    }]);
    let r = run_scenario("dir-corruption", &corruption_spec(18), &plan).unwrap();
    assert_aborted(&r, "section directory checksum mismatch");
    assert_eq!(r.phases_run, 0);
}

// ---- combined churn + determinism of the harness itself ----

fn churn_report() -> ChaosReport {
    let mut spec = SimSpec::new(42);
    spec.lease_ms = 1500;
    let plan = FaultPlan::random(42, spec.phases, spec.topo.paths(), 6);
    assert!(!plan.faults.is_empty());
    run_scenario("combined-churn", &spec, &plan).unwrap()
}

#[test]
fn chaos_combined_churn() {
    // A seeded random mix of kills, preemptions, stragglers, delayed and
    // reordered publication across all phases.
    let r = churn_report();
    assert_converged(&r);
    assert_eq!(
        r.fired.len(),
        r.planned.len(),
        "every planned fault must fire: planned {:?}, fired {:?}",
        r.planned,
        r.fired
    );
    assert_eq!(r.completed, 12);
    assert_eq!(r.dead_tasks, 0);
}

#[test]
fn chaos_report_deterministic_under_fixed_seed() {
    // The whole harness — plan generation, fault delivery, queue
    // accounting, digests, verdict — must reproduce byte-for-byte from
    // the seed, or sweep reports could not be compared across runs.
    let a = churn_report().to_json().to_string();
    let b = churn_report().to_json().to_string();
    assert_eq!(a, b, "same seed produced different ChaosReports");
}

// ---- weekly sweep: many random seeds, reports uploaded as artifacts ----

/// `cargo test -q --test integration_chaos -- --ignored --nocapture`
/// (or `make chaos-sweep`). Env: `DIPACO_CHAOS_SEEDS` (count, default
/// 20), `DIPACO_CHAOS_SEED0` (first seed, default 1000). Writes one
/// ChaosReport JSON per seed under `results/chaos/`.
#[test]
#[ignore]
fn chaos_sweep_random_seeds() {
    let n: u64 = std::env::var("DIPACO_CHAOS_SEEDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(20);
    let seed0: u64 = std::env::var("DIPACO_CHAOS_SEED0")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1000);
    let out_dir = std::path::Path::new("results/chaos");
    std::fs::create_dir_all(out_dir).unwrap();
    let mut failures = Vec::new();
    for i in 0..n {
        let seed = seed0.wrapping_add(i);
        let mut spec = SimSpec::new(seed);
        spec.lease_ms = 1500;
        let plan = FaultPlan::random(seed, spec.phases, spec.topo.paths(), 6);
        let r = run_scenario(&format!("sweep-{seed}"), &spec, &plan).unwrap();
        std::fs::write(
            out_dir.join(format!("report_{seed}.json")),
            r.to_json().to_string_pretty(),
        )
        .unwrap();
        println!(
            "seed {seed}: {:?} ({} planned, {} fired, {} requeues)",
            r.verdict,
            r.planned.len(),
            r.fired.len(),
            r.requeues
        );
        if !r.is_pass() {
            failures.push(seed);
        }
    }
    assert!(failures.is_empty(), "chaos sweep failed for seeds {failures:?}");
}

/// Transport-plane half of the weekly sweep: seeded random drop / delay /
/// duplicate / truncate faults against the TCP exchange, each run judged
/// against the same seed's FILESYSTEM reference. Same env knobs as
/// `chaos_sweep_random_seeds`; writes `report_net_{seed}.json`.
#[test]
#[ignore]
fn chaos_sweep_random_net_faults() {
    let n: u64 = std::env::var("DIPACO_CHAOS_SEEDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(20);
    let seed0: u64 = std::env::var("DIPACO_CHAOS_SEED0")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1000);
    let out_dir = std::path::Path::new("results/chaos");
    std::fs::create_dir_all(out_dir).unwrap();
    let mut failures = Vec::new();
    for i in 0..n {
        let seed = seed0.wrapping_add(i);
        let spec = tcp_spec(seed);
        let plan = FaultPlan::random_net(seed, spec.phases, spec.topo.paths(), 4);
        let r = run_scenario_vs(
            &format!("net-sweep-{seed}"),
            &spec,
            &SimSpec::new(seed),
            &plan,
        )
        .unwrap();
        std::fs::write(
            out_dir.join(format!("report_net_{seed}.json")),
            r.to_json().to_string_pretty(),
        )
        .unwrap();
        println!(
            "net seed {seed}: {:?} ({} planned, {} fired)",
            r.verdict,
            r.planned.len(),
            r.fired.len()
        );
        if !r.is_pass() {
            failures.push(seed);
        }
    }
    assert!(failures.is_empty(), "net chaos sweep failed for seeds {failures:?}");
}
