//! Integration tests for the §2.6 serving subsystem, driven through the
//! public API with a synthetic executor (no artifacts needed).
//!
//! The headline regression: per-document path assignment is honored under
//! skewed concurrent load — the old demo executed every document of a
//! batch on the path of the batch's FIRST document.

use std::collections::HashMap;
use std::time::Duration;

use dipaco::config::ServeConfig;
use dipaco::serve::server::Server;
use dipaco::testkit::exec::{logging_fleet, LoggingExec};
use dipaco::testkit::routers::{one_hot, one_hot_router};
use dipaco::util::rng::Rng;

const SEQ: usize = 16;
const BATCH: usize = 4;

fn fleet(
    paths: usize,
    delay: Duration,
) -> (
    Vec<LoggingExec>,
    std::sync::Arc<std::sync::Mutex<Vec<(usize, i32)>>>,
) {
    logging_fleet(paths, BATCH, SEQ, delay)
}

#[test]
fn skewed_concurrent_load_routes_per_document() {
    let paths = 4;
    let (execs, log) = fleet(paths, Duration::from_micros(200));
    let server = Server::start(&ServeConfig::default(), one_hot_router(paths), execs);

    // Skewed assignment: path p gets weight proportional to 2^(paths-p).
    let mut rng = Rng::new(42);
    let n = 200;
    let assignment: Vec<usize> = (0..n)
        .map(|_| {
            let x = rng.f64() * 15.0;
            if x < 8.0 {
                0
            } else if x < 12.0 {
                1
            } else if x < 14.0 {
                2
            } else {
                3
            }
        })
        .collect();

    // 4 concurrent clients submit interleaved slices of the stream.
    let responses = std::thread::scope(|s| {
        let handles: Vec<_> = (0..4)
            .map(|w| {
                let server = &server;
                let assignment = &assignment;
                s.spawn(move || {
                    let mut tickets = Vec::new();
                    for i in (w..assignment.len()).step_by(4) {
                        let mut toks = vec![0i32; SEQ];
                        toks[0] = i as i32; // marker
                        let t = server
                            .submit(&one_hot(4, assignment[i]), toks)
                            .expect("park policy admits everything");
                        tickets.push((i, t));
                    }
                    tickets
                        .into_iter()
                        .map(|(i, t)| (i, t.wait().expect("served")))
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("client thread"))
            .collect::<Vec<_>>()
    });
    let report = server.shutdown();

    // Every document answered by ITS OWN assigned path.
    assert_eq!(responses.len(), n);
    for (i, resp) in &responses {
        assert_eq!(resp.path, assignment[*i], "doc {i} served by wrong path");
    }
    // ...and actually EXECUTED there (not just labeled): the executor log
    // pins each marker to the path whose worker scored it.
    for &(path, marker) in log.lock().unwrap().iter() {
        assert_eq!(assignment[marker as usize], path, "doc {marker} ran on wrong path");
    }
    // Load accounting matches the skewed assignment exactly.
    let mut expect: HashMap<usize, u64> = HashMap::new();
    for &p in &assignment {
        *expect.entry(p).or_default() += 1;
    }
    for p in 0..paths {
        assert_eq!(report.per_path_served[p], *expect.get(&p).unwrap_or(&0));
    }
    assert_eq!(report.served, n as u64);
    assert_eq!(report.rejected, 0);
    assert!(report.p50_ms <= report.p95_ms && report.p95_ms <= report.p99_ms);
    assert!(report.tok_per_s > 0.0);
    assert!(report.mean_batch_fill >= 1.0 && report.mean_batch_fill <= BATCH as f64);
}

#[test]
fn overload_rejects_visibly_and_serves_the_rest() {
    let (execs, _log) = fleet(1, Duration::from_millis(20));
    let cfg = ServeConfig {
        queue_cap: 2,
        reject_on_full: true,
        max_wait_ms: 1,
        ..Default::default()
    };
    let server = Server::start(&cfg, one_hot_router(1), execs);
    let mut tickets = Vec::new();
    let mut rejected = 0u64;
    for _ in 0..60 {
        match server.submit_to(0, vec![0; SEQ]) {
            Ok(t) => tickets.push(t),
            Err(_) => rejected += 1,
        }
    }
    assert!(rejected > 0, "overload must reject with a 2-slot queue");
    for t in tickets {
        assert!(t.wait().is_ok(), "admitted implies served");
    }
    let report = server.shutdown();
    assert_eq!(report.served + report.rejected, 60);
    assert_eq!(report.rejected, rejected);
}

#[test]
fn lone_request_is_flushed_by_deadline_not_stuck() {
    let (execs, _log) = fleet(2, Duration::ZERO);
    let cfg = ServeConfig {
        max_wait_ms: 10,
        ..Default::default()
    };
    let server = Server::start(&cfg, one_hot_router(2), execs);
    let t = server.submit(&one_hot(2, 1), vec![0; SEQ]).unwrap();
    let resp = t
        .wait_timeout(Duration::from_secs(5))
        .expect("deadline flush must serve a lone request")
        .expect("lone request scores cleanly");
    assert_eq!(resp.path, 1);
    assert_eq!(resp.batch_fill, 1, "nothing else queued: fill is exactly 1");
    let report = server.shutdown();
    assert_eq!(report.served, 1);
}
