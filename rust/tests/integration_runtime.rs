//! Integration: the AOT bridge. Loads real `artifacts/test/` HLO text into
//! the PJRT engine and checks shapes, determinism, numerics, and training
//! behaviour end to end. Requires `make artifacts` (skips otherwise).

use dipaco::runtime::engine::{artifact_dir, Engine};

fn engine() -> Option<Engine> {
    let dir = artifact_dir("test");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/test not built");
        return None;
    }
    Some(Engine::load(&dir).expect("engine load"))
}

fn fake_tokens(engine: &Engine, seq: usize, seed: u64) -> Vec<i32> {
    let mc = engine.model();
    let mut rng = dipaco::util::rng::Rng::new(seed);
    (0..mc.batch * seq)
        .map(|_| rng.gen_range(mc.vocab) as i32)
        .collect()
}

#[test]
fn init_is_deterministic_and_sized() {
    let Some(engine) = engine() else { return };
    let a = engine.init(42).unwrap();
    let b = engine.init(42).unwrap();
    let c = engine.init(43).unwrap();
    assert_eq!(a.len(), engine.manifest.total_params);
    assert_eq!(a, b);
    assert_ne!(a, c);
    // LN scales initialized to 1: check one leaf
    let leaf = engine.manifest.leaf("block0.ln1.scale").unwrap();
    assert!(a[leaf.range()].iter().all(|&x| (x - 1.0).abs() < 1e-6));
}

#[test]
fn train_step_reduces_loss_on_repeated_batch() {
    let Some(engine) = engine() else { return };
    let mc = engine.model().clone();
    let n = engine.manifest.total_params;
    let mut theta = engine.init(0).unwrap();
    let mut m = vec![0.0; n];
    let mut v = vec![0.0; n];
    let tokens = fake_tokens(&engine, mc.seq_train, 1);
    let mut first = None;
    let mut last = 0.0;
    for i in 0..12 {
        let out = engine
            .train_step(&theta, &m, &v, (i + 1) as f32, 1e-3, &tokens)
            .unwrap();
        theta = out.theta;
        m = out.m;
        v = out.v;
        last = out.loss;
        first.get_or_insert(out.loss);
        assert!(out.loss.is_finite());
    }
    let first = first.unwrap();
    assert!(
        last < first - 0.2,
        "loss did not drop: {first} -> {last}"
    );
}

#[test]
fn token_logprobs_shapes_and_range() {
    let Some(engine) = engine() else { return };
    let mc = engine.model().clone();
    let theta = engine.init(0).unwrap();
    for seq in [mc.seq_train, mc.seq_eval] {
        let tokens = fake_tokens(&engine, seq, 2);
        let lp = engine.token_logprobs(&theta, &tokens, seq).unwrap();
        assert_eq!(lp.len(), mc.batch * (seq - 1));
        assert!(lp.iter().all(|&x| x <= 1e-4 && x.is_finite()));
        // near-uniform at init: mean logprob ~ -ln(vocab)
        let mean = lp.iter().map(|&x| x as f64).sum::<f64>() / lp.len() as f64;
        let uniform = -(mc.vocab as f64).ln();
        assert!(
            (mean - uniform).abs() < 1.0,
            "mean lp {mean} vs uniform {uniform}"
        );
    }
}

#[test]
fn features_shape_and_determinism() {
    let Some(engine) = engine() else { return };
    let mc = engine.model().clone();
    let theta = engine.init(0).unwrap();
    let tokens = fake_tokens(&engine, mc.prefix, 3);
    let z = engine.features(&theta, &tokens).unwrap();
    assert_eq!(z.len(), mc.batch * mc.d_model);
    assert!(z.iter().all(|x| x.is_finite()));
    let z2 = engine.features(&theta, &tokens).unwrap();
    assert_eq!(z, z2);
}

#[test]
fn grad_step_plus_adam_update_matches_train_step() {
    let Some(mut engine) = engine() else { return };
    engine.ensure_loaded("grad_step").unwrap();
    engine.ensure_loaded("adam_update").unwrap();
    let n = engine.manifest.total_params;
    let theta = engine.init(5).unwrap();
    let m = vec![0.0; n];
    let v = vec![0.0; n];
    let tokens = fake_tokens(&engine, engine.model().seq_train, 4);
    let a = engine.train_step(&theta, &m, &v, 1.0, 1e-3, &tokens).unwrap();
    let (g, loss) = engine.grad_step(&theta, &tokens).unwrap();
    assert!((loss - a.loss).abs() < 1e-5);
    let (theta_b, m_b, v_b) = engine.adam_update(&theta, &m, &v, &g, 1.0, 1e-3).unwrap();
    for i in (0..n).step_by(97) {
        assert!(
            (a.theta[i] - theta_b[i]).abs() < 1e-5,
            "theta[{i}] {} vs {}",
            a.theta[i],
            theta_b[i]
        );
        assert!((a.m[i] - m_b[i]).abs() < 1e-6);
        assert!((a.v[i] - v_b[i]).abs() < 1e-9);
    }
}

#[test]
fn concurrent_execution_is_safe_and_deterministic() {
    // The worker pool shares one Engine across threads; PJRT must return
    // identical results under concurrency.
    let Some(engine) = engine() else { return };
    let engine = std::sync::Arc::new(engine);
    let mc = engine.model().clone();
    let theta = engine.init(0).unwrap();
    let tokens = fake_tokens(&engine, mc.seq_train, 6);
    let expect = engine.token_logprobs(&theta, &tokens, mc.seq_train).unwrap();
    std::thread::scope(|s| {
        for _ in 0..4 {
            let engine = std::sync::Arc::clone(&engine);
            let theta = theta.clone();
            let tokens = tokens.clone();
            let expect = expect.clone();
            s.spawn(move || {
                for _ in 0..3 {
                    let lp = engine
                        .token_logprobs(&theta, &tokens, engine.model().seq_train)
                        .unwrap();
                    assert_eq!(lp, expect);
                }
            });
        }
    });
}

#[test]
fn missing_entrypoint_is_a_clean_error() {
    let Some(mut engine) = engine() else { return };
    let err = engine.ensure_loaded("nonexistent").unwrap_err();
    assert!(format!("{err:#}").contains("nonexistent"));
}

#[test]
fn fused_train_steps_matches_per_step_loop() {
    // §Perf optimization correctness: tau fused steps (lax.scan in HLO)
    // must reproduce the per-step dispatch loop exactly.
    let Some(engine) = engine() else { return };
    let mc = engine.model().clone();
    if mc.tau == 0 || !engine.has("train_steps") {
        eprintln!("skipping: artifacts built without train_steps");
        return;
    }
    let n = engine.manifest.total_params;
    let theta0 = engine.init(3).unwrap();
    let tau = mc.tau;
    let mut rng = dipaco::util::rng::Rng::new(9);
    let batches: Vec<Vec<i32>> = (0..tau)
        .map(|_| {
            (0..mc.batch * mc.seq_train)
                .map(|_| rng.gen_range(mc.vocab) as i32)
                .collect()
        })
        .collect();
    let lrs: Vec<f32> = (0..tau).map(|i| 1e-3 - (i as f32) * 1e-5).collect();

    // per-step loop
    let (mut theta, mut m, mut v) = (theta0.clone(), vec![0.0; n], vec![0.0; n]);
    let mut losses_a = Vec::new();
    for i in 0..tau {
        let out = engine
            .train_step(&theta, &m, &v, (i + 1) as f32, lrs[i], &batches[i])
            .unwrap();
        theta = out.theta;
        m = out.m;
        v = out.v;
        losses_a.push(out.loss);
    }
    // fused
    let flat: Vec<i32> = batches.concat();
    let (theta_b, m_b, v_b, losses_b) = engine
        .train_steps(&theta0, &vec![0.0; n], &vec![0.0; n], 0.0, &lrs, &flat)
        .unwrap();
    assert_eq!(losses_b.len(), tau);
    for i in 0..tau {
        assert!(
            (losses_a[i] - losses_b[i]).abs() < 1e-4,
            "loss[{i}] {} vs {}",
            losses_a[i],
            losses_b[i]
        );
    }
    for i in (0..n).step_by(131) {
        assert!((theta[i] - theta_b[i]).abs() < 1e-4, "theta[{i}]");
        assert!((m[i] - m_b[i]).abs() < 1e-5, "m[{i}]");
        assert!((v[i] - v_b[i]).abs() < 1e-7, "v[{i}]");
    }
}
