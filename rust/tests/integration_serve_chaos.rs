//! Serve-chaos harness (DESIGN.md "Failure model", serving rows): named
//! fault-injection scenarios over the real serving stack — admission
//! front-end, per-path circuit breakers, supervised path workers,
//! degraded-mode routing — judged by the no-hung-ticket oracle in
//! `chaos::oracle::run_serve_scenario`.
//!
//! Pass criteria per scenario:
//! * every submission resolves: a score, a redirect to the runner-up
//!   path, or a loud `ServeError` — never a hang;
//! * every planned fault fires (budgets fully delivered);
//! * every faulted path trips its breaker AND recovers (breaker closed,
//!   worker healthy) once the fault budget drains;
//! * the whole report reproduces byte-for-byte from the seed.
//!
//! Engine-free: the backend is a synthetic instant executor; all faults
//! come from the `ChaosExec` wrapper.

use dipaco::chaos::oracle::{run_serve_scenario, ServeChaosReport, ServeScenarioSpec};
use dipaco::chaos::plan::{ServeFault, ServeFaultPlan};

fn assert_pass(r: &ServeChaosReport) {
    assert!(
        r.is_pass(),
        "scenario {} violated serving invariants: {:?}\nreport: {}",
        r.scenario,
        r.violations,
        r.to_json().to_string_pretty()
    );
    assert_eq!(r.hung, 0);
    assert!(r.unfired.is_empty(), "unfired faults: {:?}", r.unfired);
}

// ---- tentpole acceptance scenario ----

fn panic_storm_report() -> ServeChaosReport {
    // One path's executor panics repeatedly under load.
    let spec = ServeScenarioSpec::new(71);
    let plan = ServeFaultPlan::new(vec![ServeFault::PanicExec { path: 1, batches: 3 }]);
    run_serve_scenario("panic-storm", &spec, &plan)
}

#[test]
fn serve_chaos_panic_storm_converges_to_redirect_then_recovery() {
    // The acceptance chain: panicking executor -> supervisor catches and
    // restarts -> breaker opens on the error burst -> traffic redirects
    // to the router's runner-up -> zero hung tickets -> once the faults
    // stop, half-open probes close the breaker and the path is Healthy.
    let r = panic_storm_report();
    assert_pass(&r);
    assert_eq!(r.errored, 3, "every panicked batch resolved loudly");
    assert_eq!(r.per_path_trips, vec![0, 1, 0], "exactly one trip, on path 1");
    assert!(r.redirected > 0, "open breaker must redirect traffic");
    assert_eq!(r.shed, 0);
    assert_eq!(r.refused, 0);
    assert_eq!(r.final_breaker, vec!["closed", "closed", "closed"]);
    assert_eq!(r.final_health, vec!["healthy", "healthy", "healthy"]);
}

#[test]
fn serve_chaos_report_byte_identical_across_runs() {
    // Two full runs of the same seeded scenario — real threads, real
    // panics, real restarts — must serialize to the same bytes, or sweep
    // reports could not be diffed across runs.
    let a = panic_storm_report().to_json().to_string();
    let b = panic_storm_report().to_json().to_string();
    assert_eq!(a, b, "same seed produced different ServeChaosReports");
}

// ---- the other fault kinds ----

#[test]
fn serve_chaos_wedged_batches_trip_and_recover() {
    // A wedged batch (stalls, then killed with an error) must trip the
    // breaker via the error-rate condition and resolve its tickets.
    let spec = ServeScenarioSpec::new(72);
    let plan = ServeFaultPlan::new(vec![ServeFault::WedgeBatch {
        path: 0,
        batches: 3,
        wedge_ms: 30,
    }]);
    let r = run_serve_scenario("wedged-batch", &spec, &plan);
    assert_pass(&r);
    assert_eq!(r.errored, 3);
    assert_eq!(r.per_path_trips, vec![1, 0, 0]);
    assert!(r.redirected > 0);
}

#[test]
fn serve_chaos_slow_executor_trips_on_latency() {
    // A slow executor still answers correctly — the breaker must trip on
    // the latency condition alone (no errors anywhere).
    let spec = ServeScenarioSpec::new(73);
    let plan = ServeFaultPlan::new(vec![ServeFault::SlowExec {
        path: 2,
        batches: 3,
        delay_ms: 25,
    }]);
    let r = run_serve_scenario("slow-exec", &spec, &plan);
    assert_pass(&r);
    assert_eq!(r.errored, 0, "slow batches still answer");
    assert_eq!(r.per_path_trips, vec![0, 0, 1]);
    assert!(r.redirected > 0, "latency-tripped path must shed its traffic");
}

#[test]
fn serve_chaos_multi_path_faults_leave_a_healthy_fallback() {
    // Two of four paths faulted at once (different kinds): the healthy
    // pair absorbs the redirects and both sick paths recover.
    let mut spec = ServeScenarioSpec::new(74);
    spec.paths = 4;
    let plan = ServeFaultPlan::new(vec![
        ServeFault::PanicExec { path: 0, batches: 3 },
        ServeFault::WedgeBatch {
            path: 3,
            batches: 3,
            wedge_ms: 20,
        },
    ]);
    let r = run_serve_scenario("multi-path", &spec, &plan);
    assert_pass(&r);
    assert_eq!(r.errored, 6);
    assert_eq!(r.per_path_trips, vec![1, 0, 0, 1]);
    assert_eq!(r.final_breaker, vec!["closed"; 4]);
    assert_eq!(r.final_health, vec!["healthy"; 4]);
}

// ---- weekly sweep: many random seeds, reports uploaded as artifacts ----

/// `cargo test -q --test integration_serve_chaos -- --ignored --nocapture`
/// (or `make chaos-serve-sweep`). Env: `DIPACO_CHAOS_SEEDS` (count,
/// default 10), `DIPACO_CHAOS_SEED0` (first seed, default 2000). Writes
/// one ServeChaosReport JSON per seed under `results/chaos/`.
#[test]
#[ignore]
fn serve_chaos_sweep_random_seeds() {
    let n: u64 = std::env::var("DIPACO_CHAOS_SEEDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(10);
    let seed0: u64 = std::env::var("DIPACO_CHAOS_SEED0")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2000);
    let out_dir = std::path::Path::new("results/chaos");
    std::fs::create_dir_all(out_dir).unwrap();
    let mut failures = Vec::new();
    for i in 0..n {
        let seed = seed0.wrapping_add(i);
        let mut spec = ServeScenarioSpec::new(seed);
        spec.paths = 4;
        let plan = ServeFaultPlan::random(seed, spec.paths, 2, spec.fault_batches);
        let r = run_serve_scenario(&format!("serve-sweep-{seed}"), &spec, &plan);
        std::fs::write(
            out_dir.join(format!("serve_report_{seed}.json")),
            r.to_json().to_string_pretty(),
        )
        .unwrap();
        println!(
            "seed {seed}: pass={} ({} planned, {} redirected, {} errored, {} hung)",
            r.is_pass(),
            r.planned.len(),
            r.redirected,
            r.errored,
            r.hung
        );
        if !r.is_pass() {
            failures.push(seed);
        }
    }
    assert!(
        failures.is_empty(),
        "serve chaos sweep failed for seeds {failures:?}"
    );
}
