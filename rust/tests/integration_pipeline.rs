//! Integration: the full DiPaCo recipe (routing -> phases -> discriminative
//! re-shard -> eval) plus the fully-synchronous ablation, on the test
//! preset. Requires `make artifacts` (skips otherwise).

use std::sync::Arc;

use dipaco::config::{CorpusConfig, DilocoConfig, RoutingConfig, RunConfig, TopologySpec};
use dipaco::data::corpus::Corpus;
use dipaco::data::dataset::Sharding;
use dipaco::routing::features::extract_features;
use dipaco::routing::router::{domain_alignment, fit_generative, shard_by_router};
use dipaco::runtime::engine::{artifact_dir, Engine};
use dipaco::topology::Topology;
use dipaco::train::dipaco::DipacoRecipe;
use dipaco::train::sync::train_sync;
use dipaco::util::rng::Rng;

fn setup() -> Option<(Arc<Engine>, Arc<Corpus>)> {
    let dir = artifact_dir("test");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/test not built");
        return None;
    }
    let engine = Arc::new(Engine::load(&dir).unwrap());
    let corpus = Arc::new(Corpus::synthetic(&CorpusConfig {
        n_domains: 4,
        n_docs: 400,
        doc_len: (80, 140),
        skew: 0.2,
        seed: 9,
    }));
    Some((engine, corpus))
}

fn rundir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("dipaco-pl-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn generative_routing_finds_domain_structure() {
    let Some((engine, corpus)) = setup() else { return };
    // Train the base briefly so features carry signal, then check that
    // k-means shards align with ground-truth domains far above chance.
    let trainer = dipaco::train::dense::DenseTrainer::new(
        Arc::clone(&engine),
        Arc::clone(&corpus),
        DilocoConfig {
            total_steps: 200,
            warmup_steps: 5,
            peak_lr: 2e-3,
            ..Default::default()
        },
    );
    let base = trainer.train_from_scratch(&corpus.train, 200, 3).unwrap().theta;
    let feats = extract_features(&engine, &base, &corpus.train, &corpus).unwrap();
    let mut rng = Rng::new(4);
    let router = fit_generative(&feats, 4, None, &RoutingConfig::default(), &mut rng);
    let assigns: Vec<usize> = feats.iter().map(|z| router.assign(z)).collect();
    let alignment = domain_alignment(&corpus, &corpus.train, &assigns);
    // chance is ~0.25-0.4 for 4 balanced-ish clusters; structure should push
    // it well above
    // The d=16 2-layer test model has weak features; the path preset
    // reaches >0.9 (see results/e2e). Chance here is ~0.3.
    assert!(alignment > 0.45, "alignment {alignment}");
    // sharding is usable
    let sharding = shard_by_router(&router, &corpus.train, &feats, 4, 1, 0.1, 5);
    assert!(sharding.shards.iter().all(|s| !s.is_empty()));
}

#[test]
fn recipe_end_to_end_improves_over_base() {
    let Some((engine, corpus)) = setup() else { return };
    let diloco = DilocoConfig {
        inner_steps: 10,
        total_steps: 120,
        warmup_steps: 5,
        peak_lr: 2e-3,
        ..Default::default()
    };
    // pretrain base
    let trainer = dipaco::train::dense::DenseTrainer::new(
        Arc::clone(&engine),
        Arc::clone(&corpus),
        diloco.clone(),
    );
    let base = trainer.train_from_scratch(&corpus.train, 40, 3).unwrap().theta;
    let base_ppl = dipaco::eval::ppl_docs(
        &engine,
        &base,
        &corpus.valid,
        &corpus,
        engine.model().seq_eval,
    )
    .unwrap();

    let recipe = DipacoRecipe {
        engine: Arc::clone(&engine),
        corpus: Arc::clone(&corpus),
        spec: TopologySpec::grid(vec![2, 2]),
        diloco,
        routing: RoutingConfig::default(),
        run: RunConfig {
            workers: 3,
            outer_executors: 2,
            ..Default::default()
        },
        rundir: rundir("recipe"),
        early_stop: true,
        holdout_frac: 0.1,
        grid: Some((2, 2)),
    };
    let result = recipe.train(base, 4, 2).unwrap();
    assert_eq!(result.thetas.len(), 4);
    assert_eq!(result.early_stopped.len(), 4);
    assert_eq!(result.phase_stats.len(), 6);
    // loss curve is recorded and decreasing overall
    assert!(result.loss_curve.len() == 6);
    let ppl = result.eval_routed_once(&engine, &corpus).unwrap();
    assert!(
        ppl < base_ppl,
        "DiPaCo ({ppl:.3}) should beat the 40-step base ({base_ppl:.3})"
    );
    // discriminative router is the final router
    assert_eq!(result.router.kind(), "discriminative");
}

#[test]
fn sync_training_roughly_matches_diloco_direction() {
    let Some((engine, corpus)) = setup() else { return };
    // §4.5 ablation machinery: sync trainer must run and reduce loss.
    let mut engine_mut = Engine::load(&artifact_dir("test")).unwrap();
    engine_mut.ensure_loaded("grad_step").unwrap();
    let engine = Arc::new(engine_mut);
    let spec = TopologySpec::grid(vec![2]);
    let topo = Topology::build(&engine.manifest, &spec);
    let sharding = Sharding::random(&corpus, 2, 0.0, 7);
    let base = engine.init(0).unwrap();
    let res = train_sync(
        &engine,
        &corpus,
        &sharding,
        &topo,
        &base,
        &DilocoConfig {
            total_steps: 30,
            warmup_steps: 3,
            peak_lr: 2e-3,
            ..Default::default()
        },
        30,
        1,
        2,
    )
    .unwrap();
    let first = res.loss_curve.first().unwrap().1;
    let last = res.loss_curve.last().unwrap().1;
    assert!(last < first - 0.1, "sync training did not progress: {first} -> {last}");
}

#[test]
fn chunked_routing_machinery_works() {
    let Some((engine, corpus)) = setup() else { return };
    let base = engine.init(0).unwrap();
    // two fake "paths": base init with different seeds
    let mut thetas = std::collections::HashMap::new();
    thetas.insert(0usize, engine.init(10).unwrap());
    thetas.insert(1usize, engine.init(11).unwrap());
    let docs: Vec<usize> = corpus.valid.iter().copied().take(8).collect();
    let mc = engine.model().clone();
    let scores =
        dipaco::eval::all_path_logprobs(&engine, &thetas, &docs, &corpus, mc.seq_eval).unwrap();
    // fixed-path and oracle evals bracket any learned router
    let w = 8;
    let fixed = dipaco::eval::ppl_chunked(&scores, docs.len(), mc.seq_eval, mc.prefix, w, |_, _| 0);
    let oracle = dipaco::eval::ppl_chunked_oracle(&scores, docs.len(), mc.seq_eval, mc.prefix, w);
    assert!(oracle <= fixed);
    // learned chunk router end to end
    let router = dipaco::routing::router::ChunkRouter::train(
        &engine,
        &base,
        &thetas,
        &docs,
        &corpus,
        w,
        &RoutingConfig {
            logistic_epochs: 10,
            ..Default::default()
        },
    )
    .unwrap();
    let choices = router.route_docs(&engine, &base, &docs, &corpus, w).unwrap();
    assert_eq!(choices.len(), docs.len());
    let learned = dipaco::eval::ppl_chunked(&scores, docs.len(), mc.seq_eval, mc.prefix, w, |d, c| {
        choices[d].get(c).copied().unwrap_or(0)
    });
    assert!(learned >= oracle - 1e-9);
    assert!(learned.is_finite());
}
