//! Integration: the §3 infrastructure running real training on the test
//! preset — worker pool + queue + DB + sharded outer executors + monitor,
//! with failure injection. Requires `make artifacts` (skips otherwise).

use std::sync::Arc;
use std::time::Duration;

use dipaco::config::{DilocoConfig, RunConfig, TopologySpec};
use dipaco::coordinator::monitor::Monitor;
use dipaco::coordinator::phases::DipacoRun;
use dipaco::data::corpus::Corpus;
use dipaco::data::dataset::Sharding;
use dipaco::runtime::engine::{artifact_dir, Engine};
use dipaco::topology::Topology;

fn setup() -> Option<(Arc<Engine>, Arc<Corpus>)> {
    let dir = artifact_dir("test");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/test not built");
        return None;
    }
    let engine = Arc::new(Engine::load(&dir).unwrap());
    let corpus = Arc::new(Corpus::synthetic(&dipaco::config::CorpusConfig {
        n_domains: 4,
        n_docs: 300,
        doc_len: (80, 140),
        skew: 0.0,
        seed: 5,
    }));
    Some((engine, corpus))
}

fn diloco(inner: usize, total: usize) -> DilocoConfig {
    DilocoConfig {
        inner_steps: inner,
        total_steps: total,
        warmup_steps: 5,
        peak_lr: 2e-3,
        ..Default::default()
    }
}

fn rundir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("dipaco-it-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn dipaco_phases_train_and_average() {
    let Some((engine, corpus)) = setup() else { return };
    let spec = TopologySpec::grid(vec![2, 2]);
    let topo = Arc::new(Topology::build(&engine.manifest, &spec));
    let sharding = Arc::new(Sharding::random(&corpus, topo.paths, 0.1, 1));
    let base = engine.init(0).unwrap();
    let mut run = DipacoRun::new(
        Arc::clone(&engine),
        Arc::clone(&corpus),
        sharding,
        Arc::clone(&topo),
        &base,
        diloco(8, 64),
        RunConfig {
            workers: 3,
            outer_executors: 2,
            lease_ms: 60_000,
            ..Default::default()
        },
        rundir("phases"),
        true, // early stopping evals ride the queue
    )
    .unwrap();
    run.run(4).unwrap();
    // losses decrease over phases
    let losses: Vec<f64> = run.stats.iter().map(|s| s.mean_train_loss).collect();
    assert_eq!(losses.len(), 4);
    assert!(
        losses[3] < losses[0] - 0.1,
        "no training progress: {losses:?}"
    );
    // every phase produced one checkpoint per path (dedup'd)
    for phase in 0..4 {
        assert_eq!(run.db.query(phase, "path").len(), topo.paths);
    }
    // module-sharded exchange: per phase the executors read exactly one
    // delta section per (module, path-through) pair — O(module size x
    // paths-through) bytes, never the full theta per row
    let want_sections: u64 = topo
        .all_modules()
        .iter()
        .map(|&m| topo.paths_through(m) as u64)
        .sum();
    let want_bytes: u64 = topo
        .all_modules()
        .iter()
        .map(|&m| 4 * (topo.levels[m.level].size * topo.paths_through(m)) as u64)
        .sum();
    // pre-DPC2 pipeline: EVERY executor loaded each row's full
    // theta+m+v checkpoint (executors x paths x 3 x total_params floats)
    let old_bytes = 2 * topo.paths as u64 * 3 * 4 * engine.manifest.total_params as u64;
    for s in &run.stats {
        assert_eq!(s.outer_sections_read, want_sections, "phase {}", s.phase);
        assert_eq!(s.outer_bytes_read, want_bytes, "phase {}", s.phase);
        assert!(
            s.outer_bytes_read * 4 <= old_bytes,
            "expected >= 4x I/O reduction: {} vs {old_bytes}",
            s.outer_bytes_read
        );
    }
    // modules actually moved from the base
    let store = run.store.lock().unwrap();
    let mut moved = 0;
    for m in topo.all_modules() {
        let before = topo.extract(m.level, &base);
        let after = store.get(m);
        if before.iter().zip(after).any(|(b, a)| (b - a).abs() > 1e-6) {
            moved += 1;
        }
    }
    assert_eq!(moved, topo.all_modules().len());
    drop(store);
    // paths share the stem module but differ in grid modules
    let t0 = run.path_theta(0);
    let t3 = run.path_theta(3);
    assert_ne!(t0, t3);
    // early-stopping ledger has an entry per path
    {
        let best = run.pool().ctx().best.lock().unwrap();
        assert_eq!(best.len(), topo.paths);
    }
    run.shutdown();
}

#[test]
fn progress_under_preemption_and_monitor() {
    let Some((engine, corpus)) = setup() else { return };
    let spec = TopologySpec::grid(vec![2]);
    let topo = Arc::new(Topology::build(&engine.manifest, &spec));
    let sharding = Arc::new(Sharding::random(&corpus, topo.paths, 0.0, 2));
    let base = engine.init(1).unwrap();
    let mut run = DipacoRun::new(
        Arc::clone(&engine),
        Arc::clone(&corpus),
        sharding,
        Arc::clone(&topo),
        &base,
        diloco(5, 40),
        RunConfig {
            workers: 3,
            backup_workers: 2,      // paper §3.4 backup pool
            preemption_prob: 0.4,   // heavy failure injection
            lease_ms: 1500,         // short lease so hard crashes recover fast
            outer_executors: 1,
            ..Default::default()
        },
        rundir("preempt"),
        false,
    )
    .unwrap();
    let monitor = Monitor::start(Arc::clone(run.pool()), Duration::from_millis(200));
    run.run(3).unwrap();
    let stats = run.queue().stats();
    // all tasks retired exactly once despite preemptions
    assert_eq!(stats.completed, 3 * topo.paths as u64);
    let total_requeues: u64 = run.stats.iter().map(|s| s.requeues).sum();
    assert!(total_requeues > 0, "preemption injection never fired");
    // losses still make progress
    assert!(run.stats[2].mean_train_loss < run.stats[0].mean_train_loss + 0.05);
    monitor.stop();
    run.shutdown();
}

#[test]
fn monitor_respawns_crashed_workers() {
    let Some((engine, corpus)) = setup() else { return };
    use dipaco::coordinator::db::CheckpointDb;
    use dipaco::coordinator::queue::TaskQueue;
    use dipaco::coordinator::task::{Task, TrainTask};
    use dipaco::coordinator::worker::{WorkerCtx, WorkerPool};
    use dipaco::params::checkpoint::Checkpoint;

    let sharding = Arc::new(Sharding::random(&corpus, 2, 0.0, 3));
    let topo = Arc::new(Topology::build(
        &engine.manifest,
        &TopologySpec::grid(vec![2]),
    ));
    let queue = Arc::new(TaskQueue::new(Duration::from_secs(30)));
    let db = Arc::new(CheckpointDb::new());
    let mut ctx = WorkerCtx::new(
        Arc::clone(&engine),
        Arc::clone(&queue),
        Arc::clone(&db),
        Arc::clone(&corpus),
        sharding,
        topo,
        diloco(2, 20),
        RunConfig {
            workers: 2,
            ..Default::default()
        },
        false,
    );
    // every task crashes its worker afterward — monitor must keep respawning
    Arc::get_mut(&mut ctx).unwrap().crash_prob = 1.0;
    let pool = WorkerPool::spawn(Arc::clone(&ctx), 2, 0);
    let monitor = Monitor::start(Arc::clone(&pool), Duration::from_millis(100));

    let dir = rundir("monitor");
    std::fs::create_dir_all(&dir).unwrap();
    let base = engine.init(0).unwrap();
    for i in 0..6u64 {
        let ckpt_in = dir.join(format!("t{i}.in.dpc"));
        Checkpoint::new()
            .with("theta", base.clone())
            .save(&ckpt_in)
            .unwrap();
        queue.push(Task::Train(TrainTask {
            id: i + 1,
            phase: 0,
            path: (i % 2) as usize,
            steps: 2,
            start_step: 0,
            ckpt_in,
            ckpt_out: dir.join(format!("t{i}.out.dpc")),
            opt_in: None,
            opt_out: dir.join(format!("t{i}.opt.dpc")),
        }))
        .expect("queue stays open for the monitor test");
    }
    queue.wait_idle(Duration::from_millis(20));
    assert_eq!(queue.stats().completed, 6);
    assert!(
        monitor.respawns.load(std::sync::atomic::Ordering::Relaxed) >= 4,
        "monitor should have respawned crashed workers"
    );
    monitor.stop();
    pool.shutdown();
}

#[test]
fn multiple_rounds_when_workers_fewer_than_paths() {
    let Some((engine, corpus)) = setup() else { return };
    let spec = TopologySpec::grid(vec![4]); // 4 paths
    let topo = Arc::new(Topology::build(&engine.manifest, &spec));
    let sharding = Arc::new(Sharding::random(&corpus, 4, 0.0, 4));
    let base = engine.init(2).unwrap();
    let mut run = DipacoRun::new(
        Arc::clone(&engine),
        Arc::clone(&corpus),
        sharding,
        Arc::clone(&topo),
        &base,
        diloco(4, 16),
        RunConfig {
            workers: 1, // one worker serves 4 paths in rounds (paper §3.4)
            outer_executors: 2,
            ..Default::default()
        },
        rundir("rounds"),
        false,
    )
    .unwrap();
    run.run(2).unwrap();
    assert_eq!(run.queue().stats().completed, 8);
    assert_eq!(run.db.query(1, "path").len(), 4);
    run.shutdown();
}
