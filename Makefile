# DiPaCo reproduction — build entrypoints.
#
# `make artifacts` is the only step that runs Python: it AOT-lowers the
# JAX/Pallas model to HLO text under artifacts/<preset>/ (see DESIGN.md,
# "AOT artifact pipeline"). Everything after is `cargo`.

PYTHON ?= python3
PRESETS ?= test path large

.PHONY: artifacts build test bench bench-ckpt chaos chaos-sweep clippy fmt

artifacts:
	@for p in $(PRESETS); do \
		echo "== lowering preset $$p"; \
		(cd python && $(PYTHON) -m compile.aot --preset $$p --out ../artifacts) || exit 1; \
	done

build:
	cargo build --release

test:
	cargo test -q

# Checkpoint-format bench: DPC1 full load vs DPC2 section access, and
# executor bytes-read-per-phase (CSV under results/bench/).
bench-ckpt:
	cargo bench --bench bench_ckpt

# Chaos harness (DESIGN.md "Failure model"): named fault-injection
# scenarios with fixed seeds, judged by convergence-equivalence oracles.
# Engine-free — no `make artifacts` needed.
chaos:
	cargo test -q --test integration_chaos

# Weekly seed sweep: random fault plans, one ChaosReport JSON per seed
# under results/chaos/. DIPACO_CHAOS_SEEDS / DIPACO_CHAOS_SEED0 override
# the count and the first seed.
chaos-sweep:
	mkdir -p results/chaos
	cargo test -q --test integration_chaos -- --ignored --nocapture

clippy:
	cargo clippy --all-targets -- -D warnings

fmt:
	cargo fmt --check
