# DiPaCo reproduction — build entrypoints.
#
# `make artifacts` is the only step that runs Python: it AOT-lowers the
# JAX/Pallas model to HLO text under artifacts/<preset>/ (see DESIGN.md,
# "AOT artifact pipeline"). Everything after is `cargo`.

PYTHON ?= python3
PRESETS ?= test path large

.PHONY: artifacts build test bench bench-ckpt clippy fmt

artifacts:
	@for p in $(PRESETS); do \
		echo "== lowering preset $$p"; \
		(cd python && $(PYTHON) -m compile.aot --preset $$p --out ../artifacts) || exit 1; \
	done

build:
	cargo build --release

test:
	cargo test -q

# Checkpoint-format bench: DPC1 full load vs DPC2 section access, and
# executor bytes-read-per-phase (CSV under results/bench/).
bench-ckpt:
	cargo bench --bench bench_ckpt

clippy:
	cargo clippy --all-targets -- -D warnings

fmt:
	cargo fmt --check
