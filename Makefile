# DiPaCo reproduction — build entrypoints.
#
# `make artifacts` is the only step that runs Python: it AOT-lowers the
# JAX/Pallas model to HLO text under artifacts/<preset>/ (see DESIGN.md,
# "AOT artifact pipeline"). Everything after is `cargo`.

PYTHON ?= python3
PRESETS ?= test path large

.PHONY: artifacts build test bench fmt

artifacts:
	@for p in $(PRESETS); do \
		echo "== lowering preset $$p"; \
		(cd python && $(PYTHON) -m compile.aot --preset $$p --out ../artifacts) || exit 1; \
	done

build:
	cargo build --release

test:
	cargo test -q

fmt:
	cargo fmt --check
