# DiPaCo reproduction — build entrypoints.
#
# `make artifacts` is the only step that runs Python: it AOT-lowers the
# JAX/Pallas model to HLO text under artifacts/<preset>/ (see DESIGN.md,
# "AOT artifact pipeline"). Everything after is `cargo`.

PYTHON ?= python3
PRESETS ?= test path large

.PHONY: artifacts build test bench bench-ckpt bench-serve bench-train bench-assembly bench-outer bench-stream bench-transport bench-all chaos chaos-serve chaos-sweep chaos-serve-sweep clippy fmt

artifacts:
	@for p in $(PRESETS); do \
		echo "== lowering preset $$p"; \
		(cd python && $(PYTHON) -m compile.aot --preset $$p --out ../artifacts) || exit 1; \
	done

build:
	cargo build --release

test:
	cargo test -q

# Checkpoint-format bench: DPC1 full load vs DPC2 section access, and
# executor bytes-read-per-phase (CSV under results/bench/).
bench-ckpt:
	cargo bench --bench bench_ckpt

# Serving-plane bench (§2.6): queueing/batching/routing overhead on a
# synthetic executor, plus the self-healing (breaker + supervisor)
# healthy-path overhead check. CSV under results/bench/bench_serve.csv.
bench-serve:
	cargo bench --bench bench_serve

# Hot-path bench: fused kernel A/B (always runs) plus PJRT entrypoint
# timings when artifacts/<preset> exist. CSV under results/bench/.
bench-train:
	cargo bench --bench bench_train_step

# Per-phase parameter plumbing: allocating vs pooled assembly, the
# data-parallel multi-path fan-out, delta split, checkpoint save/load.
bench-assembly:
	cargo bench --bench bench_assembly

# Outer-optimization executors: naive gather-then-average vs online
# sharded averaging (§3.3).
bench-outer:
	cargo bench --bench bench_outer_opt

# Streaming outer sync: published bytes per delta codec (f32/bf16/int8,
# int8 must be >= 3.5x smaller), codec encode/decode throughput, and the
# last-publish -> last-applied exchange-window gap, serial vs staggered.
bench-stream:
	cargo bench --bench bench_stream

# Section exchange plane: push throughput + p50/p99 per-section push
# latency + executor read-back, local filesystem vs TCP loopback.
bench-transport:
	cargo bench --bench bench_transport

# Every bench, then merge the per-bench BENCH_*.json baselines into
# results/bench/BENCH_summary.json.
bench-all: bench-train bench-ckpt bench-assembly bench-serve bench-outer bench-stream bench-transport
	cargo run --release -- bench-summary

# Chaos harness (DESIGN.md "Failure model"): named fault-injection
# scenarios with fixed seeds, judged by convergence-equivalence oracles.
# Engine-free — no `make artifacts` needed.
chaos:
	cargo test -q --test integration_chaos

# Serving-plane chaos (DESIGN.md "Failure model", serving rows): executor
# panic/wedge/slow fault plans over the real serving stack, judged by the
# no-hung-ticket oracle. Engine-free, fixed seeds.
chaos-serve:
	cargo test -q --test integration_serve_chaos

# Weekly seed sweep: random fault plans, one ChaosReport JSON per seed
# under results/chaos/ — includes the transport-plane half (random
# drop/delay/duplicate/truncate against the TCP exchange, report_net_*
# files). DIPACO_CHAOS_SEEDS / DIPACO_CHAOS_SEED0 override the count and
# the first seed.
chaos-sweep:
	mkdir -p results/chaos
	cargo test -q --test integration_chaos -- --ignored --nocapture

# Serving-plane counterpart: random serve fault plans, one
# ServeChaosReport JSON per seed under results/chaos/.
chaos-serve-sweep:
	mkdir -p results/chaos
	cargo test -q --test integration_serve_chaos -- --ignored --nocapture

clippy:
	cargo clippy --all-targets -- -D warnings

fmt:
	cargo fmt --check
