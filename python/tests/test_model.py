"""L2 correctness: flat-theta transformer, loss masking, AdamW step, init."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model

CFG = configs.get("test")
N = model.total_params(CFG)


@pytest.fixture(scope="module")
def theta():
    return model.init(jnp.uint32(0), CFG)


def toks(key=0, seq=None, batch=None):
    rng = np.random.RandomState(key)
    return rng.randint(
        0, CFG.vocab, (batch or CFG.batch, seq or CFG.seq_train)
    ).astype(np.int32)


# --------------------------------------------------------------------- layout


def test_layout_offsets_contiguous():
    m_off = 0
    for name, shape in model.layout(CFG):
        sz = int(np.prod(shape))
        assert sz > 0, name
        m_off += sz
    assert m_off == N


def test_flatten_unflatten_roundtrip(theta):
    p = model.unflatten(theta, CFG)
    back = model.flatten(p, CFG)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(theta))


def test_decay_mask_covers_matrices_only():
    mask = np.asarray(model.decay_mask(CFG))
    assert mask.shape == (N,)
    off = 0
    for name, shape in model.layout(CFG):
        sz = int(np.prod(shape))
        seg = mask[off : off + sz]
        expect = 1.0 if (len(shape) == 2 and ".ln" not in name) else 0.0
        assert (seg == expect).all(), name
        off += sz


# ---------------------------------------------------------------------- init


def test_init_deterministic():
    a = model.init(jnp.uint32(7), CFG)
    b = model.init(jnp.uint32(7), CFG)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = model.init(jnp.uint32(8), CFG)
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_init_structure(theta):
    p = model.unflatten(theta, CFG)
    np.testing.assert_array_equal(np.asarray(p["block0.ln1.scale"]), 1.0)
    np.testing.assert_array_equal(np.asarray(p["block0.mlp.b1"]), 0.0)
    std = float(np.asarray(p["embed.tok"]).std())
    assert 0.015 < std < 0.025


# ------------------------------------------------------------------- forward


def test_logits_shape(theta):
    t = toks()
    lg = model.logits_fn(theta, t, CFG)
    assert lg.shape == (CFG.batch, CFG.seq_train, CFG.vocab)


def test_token_logprobs_are_logprobs(theta):
    t = toks()
    lp = np.asarray(model.token_logprobs(theta, t, CFG))
    assert lp.shape == (CFG.batch, CFG.seq_train - 1)
    assert (lp <= 1e-6).all()


def test_causal_lm_property(theta):
    """Changing future tokens must not change earlier logprobs."""
    t = toks(1)
    lp1 = np.asarray(model.token_logprobs(theta, t, CFG))
    t2 = t.copy()
    t2[:, 20:] = (t2[:, 20:] + 1) % CFG.vocab
    lp2 = np.asarray(model.token_logprobs(theta, t2, CFG))
    # logp[j] depends on tokens[:, :j+2); entries with j+1 < 20 are unchanged
    np.testing.assert_allclose(lp1[:, :18], lp2[:, :18], rtol=1e-5, atol=1e-6)


def test_loss_masks_prefix(theta):
    """Loss counts only targets with index >= prefix; perturbing prefix
    TARGETS (not context) must leave the masked set's identity intact."""
    t = toks(2)
    loss = float(model.loss_fn(theta, t, CFG))
    lp = np.asarray(model.token_logprobs(theta, t, CFG))
    tgt_idx = np.arange(1, CFG.seq_train)
    mask = tgt_idx >= CFG.prefix
    manual = -lp[:, mask].mean()
    np.testing.assert_allclose(loss, manual, rtol=1e-5)


def test_features_shape_and_prefix_dependence(theta):
    t = toks(3, seq=CFG.prefix)
    z = np.asarray(model.features(theta, t, CFG))
    assert z.shape == (CFG.batch, CFG.d_model)
    t2 = t.copy()
    t2[0, 0] = (t2[0, 0] + 1) % CFG.vocab
    z2 = np.asarray(model.features(theta, t2, CFG))
    assert not np.allclose(z[0], z2[0])
    np.testing.assert_allclose(z[1:], z2[1:], rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------- optimizer


def test_train_step_matches_manual_adamw(theta):
    t = toks(4)
    m = jnp.zeros(N)
    v = jnp.zeros(N)
    step, lr = 1.0, 3e-4
    th2, m2, v2, loss = model.train_step(theta, m, v, step, lr, t, CFG)

    g = jax.grad(model.loss_fn)(theta, t, CFG)
    g = np.asarray(g, np.float64)
    th = np.asarray(theta, np.float64)
    b1, b2, eps, wd = CFG.adam_b1, CFG.adam_b2, CFG.adam_eps, CFG.weight_decay
    m_ref = (1 - b1) * g
    v_ref = (1 - b2) * g * g
    mhat = m_ref / (1 - b1**step)
    vhat = v_ref / (1 - b2**step)
    mask = np.asarray(model.decay_mask(CFG), np.float64)
    th_ref = th - lr * (mhat / (np.sqrt(vhat) + eps) + wd * mask * th)

    np.testing.assert_allclose(np.asarray(th2), th_ref, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(m2), m_ref, rtol=1e-4, atol=1e-7)
    np.testing.assert_allclose(np.asarray(v2), v_ref, rtol=1e-4, atol=1e-10)
    assert float(loss) > 0


def test_training_reduces_loss(theta):
    """A few steps on one repeated batch must overfit it."""
    t = toks(5)
    ts = jax.jit(lambda th, m, v, s, lr, tk: model.train_step(th, m, v, s, lr, tk, CFG))
    m = jnp.zeros(N)
    v = jnp.zeros(N)
    th = theta
    losses = []
    for i in range(20):
        th, m, v, loss = ts(th, m, v, float(i + 1), 1e-3, t)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.3, losses


def test_grad_step_plus_adam_update_equals_train_step(theta):
    """The sync-ablation decomposition must reproduce train_step exactly."""
    t = toks(6)
    m = jnp.zeros(N)
    v = jnp.zeros(N)
    th_a, m_a, v_a, _ = model.train_step(theta, m, v, 1.0, 1e-3, t, CFG)
    g, _ = model.grad_step(theta, t, CFG)
    th_b, m_b, v_b = model.adam_update(theta, m, v, g, 1.0, 1e-3, CFG)
    np.testing.assert_allclose(np.asarray(th_a), np.asarray(th_b), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(m_a), np.asarray(m_b), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(v_a), np.asarray(v_b), rtol=1e-6)


def test_train_steps_scan_matches_loop(theta):
    """lax.scan-fused steps must equal the unrolled per-step loop."""
    tau = CFG.tau
    rng = np.random.RandomState(9)
    batches = rng.randint(0, CFG.vocab, (tau, CFG.batch, CFG.seq_train)).astype(np.int32)
    lrs = np.linspace(1e-3, 8e-4, tau).astype(np.float32)
    m = jnp.zeros(N)
    v = jnp.zeros(N)
    th_a, m_a, v_a = theta, m, v
    losses_a = []
    for i in range(tau):
        th_a, m_a, v_a, loss = model.train_step(
            th_a, m_a, v_a, float(i + 1), float(lrs[i]), batches[i], CFG
        )
        losses_a.append(float(loss))
    th_b, m_b, v_b, losses_b = model.train_steps(
        theta, m, v, 0.0, jnp.asarray(lrs), jnp.asarray(batches), CFG
    )
    # scan vs unrolled compile to different fusion orders; tolerate
    # float-accumulation noise (observed max ~1e-5 over 20 steps).
    np.testing.assert_allclose(np.asarray(losses_b), losses_a, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(th_b), np.asarray(th_a), rtol=2e-3, atol=1e-5)
    np.testing.assert_allclose(np.asarray(v_b), np.asarray(v_a), rtol=2e-3, atol=1e-8)
