"""Build-time AOT checks: manifest agrees with the layout; emitted HLO text
parses through the same proto/text layer the rust PJRT loader uses.

(The full execute-and-compare round trip — HLO text loaded by the rust
`xla` crate and run on PJRT — is covered by rust/tests/integration_runtime.rs,
which compares against numerics recorded here at artifact-build time.)
"""

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from compile import aot, configs, model

CFG = configs.get("test")


def test_manifest_matches_layout():
    man = aot.build_manifest(CFG)
    assert man["total_params"] == model.total_params(CFG)
    off = 0
    for leaf, (name, shape) in zip(man["leaves"], model.layout(CFG)):
        assert leaf["name"] == name
        assert leaf["offset"] == off
        assert tuple(leaf["shape"]) == tuple(shape)
        off += leaf["size"]
    assert off == man["total_params"]
    assert set(man["entrypoints"]) == set(model.entrypoints(CFG))


def test_manifest_config_fields():
    man = aot.build_manifest(CFG)
    cfgd = man["config"]
    for k in ("vocab", "d_model", "n_layers", "n_heads", "d_ff",
              "seq_train", "seq_eval", "batch", "prefix", "d_head"):
        assert k in cfgd, k
    assert cfgd["d_head"] * cfgd["n_heads"] == cfgd["d_model"]


def test_hlo_text_nonempty_and_parseable():
    eps = model.entrypoints(CFG)
    fn, args = eps["features"]
    text = aot.to_hlo_text(jax.jit(fn).lower(*args))
    assert "ENTRY" in text and "HloModule" in text
    mod = xc._xla.hlo_module_from_text(text)  # rust-side parse equivalent
    assert mod is not None


def test_entrypoint_shapes():
    eps = model.entrypoints(CFG)
    n = model.total_params(CFG)
    _, a = eps["train_step"]
    assert a[0].shape == (n,) and a[5].shape == (CFG.batch, CFG.seq_train)
    _, a = eps["token_logprobs_eval"]
    assert a[1].shape == (CFG.batch, CFG.seq_eval)
    _, a = eps["features"]
    assert a[1].shape == (CFG.batch, CFG.prefix)
