"""Invariants of the L1 kernel perf model (analysis.py)."""

from compile.kernels.analysis import KernelProfile, profile_preset, VMEM_BYTES


def test_presets_fit_vmem_double_buffered():
    for preset in ("path", "large", "test"):
        p = profile_preset(preset)
        assert p.fits_vmem(), preset
        assert 2 * p.vmem_per_step() <= VMEM_BYTES


def test_paper_scale_schedule_fits():
    # The same whole-tile schedule at paper scale (S=1024, Dh=64, bf16):
    # 3x128KiB qkv + 4MiB f32 scores + 128KiB out ~= 4.5 MiB — still under
    # VMEM with double buffering, which is why the whole-tile variant (not
    # flash-style row blocking) is the right TPU adaptation here.
    p = KernelProfile(batch=512, heads=16, seq=1024, d_head=64, dtype_bytes=2)
    assert p.fits_vmem()


def test_mxu_fraction_grows_with_d_head():
    lo = KernelProfile(batch=1, heads=1, seq=128, d_head=8)
    hi = KernelProfile(batch=1, heads=1, seq=128, d_head=64)
    assert hi.mxu_fraction() > lo.mxu_fraction()
    assert 0.0 < lo.mxu_fraction() < 1.0


def test_arithmetic_intensity_grows_with_seq():
    lo = KernelProfile(batch=1, heads=1, seq=64, d_head=16)
    hi = KernelProfile(batch=1, heads=1, seq=512, d_head=16)
    assert hi.arithmetic_intensity() > lo.arithmetic_intensity()


def test_grid_covers_batch_heads():
    p = profile_preset("path")
    assert p.grid_steps() == p.batch * p.heads


def test_hbm_traffic_excludes_scores():
    # the S x S score matrix must never be counted as HBM traffic
    p = KernelProfile(batch=1, heads=1, seq=256, d_head=16)
    assert p.hbm_bytes_per_step() == 4 * 256 * 16 * 4
