"""L1 correctness: Pallas attention kernel vs pure-jnp oracle.

Hypothesis sweeps shapes/dtypes/seeds per the repro brief; every forward
value and every backward gradient must match `ref.py` to tight tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import attention
from compile.kernels.ref import attention_ref


def _rand(key, bh, s, d, dtype):
    q, k, v = jax.random.normal(jax.random.PRNGKey(key), (3, bh, s, d))
    return q.astype(dtype), k.astype(dtype), v.astype(dtype)


@settings(max_examples=25, deadline=None)
@given(
    bh=st.integers(1, 6),
    s=st.sampled_from([1, 2, 3, 8, 17, 32, 64]),
    d=st.sampled_from([1, 4, 8, 16, 32]),
    key=st.integers(0, 2**31 - 1),
)
def test_forward_matches_ref_f32(bh, s, d, key):
    q, k, v = _rand(key, bh, s, d, jnp.float32)
    out = attention(q, k, v)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    bh=st.integers(1, 4),
    s=st.sampled_from([2, 8, 32]),
    d=st.sampled_from([4, 16]),
    key=st.integers(0, 2**31 - 1),
)
def test_forward_matches_ref_bf16(bh, s, d, key):
    q, k, v = _rand(key, bh, s, d, jnp.bfloat16)
    out = attention(q, k, v).astype(jnp.float32)
    ref = attention_ref(q, k, v).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=5e-2, atol=5e-2)


@settings(max_examples=15, deadline=None)
@given(
    bh=st.integers(1, 4),
    s=st.sampled_from([2, 5, 16, 48]),
    d=st.sampled_from([4, 8, 16]),
    key=st.integers(0, 2**31 - 1),
)
def test_backward_matches_ref(bh, s, d, key):
    """Pallas backward kernel vs jax.grad through the jnp oracle."""
    q, k, v = _rand(key, bh, s, d, jnp.float32)

    def loss_pallas(q, k, v):
        return jnp.sum(jnp.sin(attention(q, k, v)))

    def loss_ref(q, k, v):
        return jnp.sum(jnp.sin(attention_ref(q, k, v)))

    g_pal = jax.grad(loss_pallas, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for gp, gr, name in zip(g_pal, g_ref, "qkv"):
        np.testing.assert_allclose(
            np.asarray(gp), np.asarray(gr), rtol=2e-4, atol=2e-4,
            err_msg=f"d{name} mismatch",
        )


def test_causality():
    """Output at position t must not depend on tokens at positions > t."""
    q, k, v = _rand(0, 2, 16, 8, jnp.float32)
    out1 = np.asarray(attention(q, k, v))
    k2 = k.at[:, 10:, :].set(99.0)
    v2 = v.at[:, 10:, :].set(-99.0)
    out2 = np.asarray(attention(q, k2, v2))
    np.testing.assert_allclose(out1[:, :10, :], out2[:, :10, :], rtol=1e-6)
    assert not np.allclose(out1[:, 10:, :], out2[:, 10:, :])


def test_first_position_is_value():
    """Position 0 attends only to itself: out[0] == v[0]."""
    q, k, v = _rand(1, 3, 9, 4, jnp.float32)
    out = np.asarray(attention(q, k, v))
    np.testing.assert_allclose(out[:, 0, :], np.asarray(v)[:, 0, :], rtol=1e-6)


def test_softmax_rows_numerically_stable():
    """Large-magnitude scores must not produce NaN/Inf."""
    q, k, v = _rand(2, 1, 8, 4, jnp.float32)
    out = np.asarray(attention(q * 1e3, k * 1e3, v))
    assert np.isfinite(out).all()


def test_grad_finite_on_degenerate_seq1():
    q, k, v = _rand(3, 2, 1, 4, jnp.float32)
    g = jax.grad(lambda q, k, v: jnp.sum(attention(q, k, v)), argnums=(0, 1, 2))(q, k, v)
    for gi in g:
        assert np.isfinite(np.asarray(gi)).all()
