"""Model presets for the DiPaCo reproduction.

These MUST stay in sync with `rust/src/config/presets.rs`: the rust side
re-reads the resolved config from each artifact's `manifest.json`, so the
manifest is the source of truth at runtime; this file is the source of
truth at compile time.

Scale substitution (see DESIGN.md): the paper's 150M-parameter path /
1.3B dense baseline become the `path` (~0.25M) / `large` (~1.7M) presets,
preserving the ~7x dense-to-path parameter ratio and the 12-block-style
decoder architecture, scaled to CPU-PJRT throughput.
"""

from dataclasses import dataclass, asdict, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int = 256          # byte-level tokenizer
    d_model: int = 64
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 256
    seq_train: int = 128      # training sequence length (paper: 1024)
    seq_eval: int = 256       # evaluation sequence length (paper: 2048)
    batch: int = 8            # per-step batch (paper: 512)
    prefix: int = 32          # router prefix, excluded from the LM loss (paper: 32)
    # Steps fused into one `train_steps` HLO via lax.scan (§Perf: one
    # host<->device round trip per chunk instead of per step). Inner
    # phases are multiples of this.
    tau: int = 20
    # AdamW (inner optimizer) — paper Table 4.
    adam_b1: float = 0.9
    adam_b2: float = 0.999
    adam_eps: float = 1e-8
    weight_decay: float = 0.1

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def to_dict(self) -> dict:
        d = asdict(self)
        d["d_head"] = self.d_head
        return d


PRESETS = {
    # A single DiPaCo path (stands in for the paper's 150M model).
    "path": ModelConfig(name="path"),
    # The dense baseline (stands in for the paper's 1.3B model, ~7x params).
    "large": ModelConfig(
        name="large", d_model=128, n_layers=8, n_heads=8, d_ff=512
    ),
    # Miniature preset used only by fast unit tests. vocab stays 256: the
    # byte tokenizer emits the full byte range.
    "test": ModelConfig(
        name="test", d_model=16, n_layers=2, n_heads=2, d_ff=32,
        seq_train=32, seq_eval=48, batch=2, prefix=16,
    ),
}


def get(name: str) -> ModelConfig:
    try:
        return PRESETS[name]
    except KeyError:
        raise SystemExit(f"unknown preset {name!r}; have {sorted(PRESETS)}")
