"""L1 — Pallas causal-attention kernels (forward AND backward).

This is the per-path compute hot spot of a DiPaCo path (a dense decoder
transformer).  The kernels are written the TPU way even though they are
executed in interpret mode on CPU-PJRT (a real-TPU lowering emits a Mosaic
custom-call the CPU plugin cannot run — see /opt/xla-example/README.md):

* grid iterates over (batch x heads); each grid step owns one (S, Dh)
  Q/K/V tile, which is the natural VMEM-resident unit at this scale
  (S<=256, Dh<=32 -> <=96 KiB of f32 per operand, far under the ~16 MiB
  VMEM budget; see EXPERIMENTS.md §Perf for the footprint table);
* the S x S score matrix is materialized per tile — at paper scale this
  would be flash-style row-blocked, at our S this whole-tile variant is
  the right VMEM/MXU trade-off (no extra HBM round trips);
* both matmuls are MXU-shaped (f32 here; bf16 inputs are covered by the
  hypothesis sweep in python/tests/test_kernel.py).

Autodiff: `pallas_call` has no VJP rule, so the module exports
`attention(q, k, v)` wrapped in `jax.custom_vjp` whose backward pass is a
second Pallas kernel recomputing the probabilities (the standard
recompute-in-backward schedule).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _causal_mask(s: int):
    i = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    return i >= j  # True where attention is allowed


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float):
    """One (batch*head) tile: o = softmax(mask(q k^T * scale)) v."""
    q = q_ref[0, :, :]  # (S, Dh)
    k = k_ref[0, :, :]
    v = v_ref[0, :, :]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    s = jnp.where(_causal_mask(q.shape[0]), s, NEG_INF)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[0, :, :] = jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _bwd_kernel(q_ref, k_ref, v_ref, do_ref, dq_ref, dk_ref, dv_ref, *, scale: float):
    """Backward for one tile, recomputing p = softmax(...).

    dV = P^T dO;  dP = dO V^T;  dS = P * (dP - rowsum(dP * P));
    dQ = dS K * scale;  dK = dS^T Q * scale.
    """
    q = q_ref[0, :, :].astype(jnp.float32)
    k = k_ref[0, :, :].astype(jnp.float32)
    v = v_ref[0, :, :].astype(jnp.float32)
    do = do_ref[0, :, :].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    s = jnp.where(_causal_mask(q.shape[0]), s, NEG_INF)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    dv = jnp.dot(p.T, do, preferred_element_type=jnp.float32)
    dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq = jnp.dot(ds, k, preferred_element_type=jnp.float32) * scale
    dk = jnp.dot(ds.T, q, preferred_element_type=jnp.float32) * scale
    dq_ref[0, :, :] = dq.astype(dq_ref.dtype)
    dk_ref[0, :, :] = dk.astype(dk_ref.dtype)
    dv_ref[0, :, :] = dv.astype(dv_ref.dtype)


def _tile_spec(s: int, d: int):
    # One (1, S, Dh) block per grid step i over the fused batch*heads axis.
    return pl.BlockSpec((1, s, d), lambda i: (i, 0, 0))


def _attention_fwd_call(q, k, v):
    bh, s, d = q.shape
    scale = 1.0 / (d ** 0.5)
    return pl.pallas_call(
        functools.partial(_fwd_kernel, scale=scale),
        grid=(bh,),
        in_specs=[_tile_spec(s, d)] * 3,
        out_specs=_tile_spec(s, d),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        interpret=True,
    )(q, k, v)


def _attention_bwd_call(q, k, v, do):
    bh, s, d = q.shape
    scale = 1.0 / (d ** 0.5)
    shp = jax.ShapeDtypeStruct((bh, s, d), q.dtype)
    return pl.pallas_call(
        functools.partial(_bwd_kernel, scale=scale),
        grid=(bh,),
        in_specs=[_tile_spec(s, d)] * 4,
        out_specs=(_tile_spec(s, d),) * 3,
        out_shape=(shp, shp, shp),
        interpret=True,
    )(q, k, v, do)


@jax.custom_vjp
def attention(q, k, v):
    """Causal multi-head attention on fused-(batch*heads) tensors.

    Args:
      q, k, v: f32/bf16 arrays of shape (batch*heads, seq, d_head).
    Returns:
      (batch*heads, seq, d_head) attention output.
    """
    return _attention_fwd_call(q, k, v)


def _attention_vjp_fwd(q, k, v):
    return _attention_fwd_call(q, k, v), (q, k, v)


def _attention_vjp_bwd(res, do):
    q, k, v = res
    return _attention_bwd_call(q, k, v, do)


attention.defvjp(_attention_vjp_fwd, _attention_vjp_bwd)
