"""Pure-jnp oracle for the Pallas attention kernel.

Used by the pytest/hypothesis suite as the correctness reference for both
the forward values and (via jax.grad on this function) the backward pass.
"""

import jax
import jax.numpy as jnp


def attention_ref(q, k, v):
    """Causal attention, shapes (batch*heads, seq, d_head)."""
    _, s, d = q.shape
    scale = 1.0 / (d ** 0.5)
    scores = jnp.einsum("bsd,btd->bst", q, k).astype(jnp.float32) * scale
    i = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    scores = jnp.where(i >= j, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bst,btd->bsd", p.astype(v.dtype), v)
