"""L1 perf analysis — VMEM footprint and MXU-utilization estimates for the
Pallas attention kernels, derived analytically from the BlockSpec schedule.

interpret=True gives CPU-numpy timings that say nothing about TPU
performance, so (per the repro brief) the kernel is optimized structurally:
this module computes, for a given (batch, heads, seq, d_head):

* VMEM bytes resident per grid step (all operand+output tiles), checked
  against the ~16 MiB/core budget;
* FLOPs per grid step and the fraction issued as MXU-shaped matmuls
  (vs VPU elementwise softmax work) — the achievable-MXU-utilization
  proxy the paper's efficiency ratio translates to;
* arithmetic intensity (FLOPs / HBM byte), vs the TPUv4 ridge point
  (~275 FLOP/byte bf16), to classify the kernel as compute- or
  memory-bound.

`python -m compile.kernels.analysis` prints the table for the presets;
EXPERIMENTS.md §Perf records it. pytest covers the invariants.
"""

from dataclasses import dataclass

VMEM_BYTES = 16 * 2 ** 20       # per-core VMEM, TPUv4-ish
MXU_RIDGE_FLOP_PER_BYTE = 275.0  # bf16 ridge point proxy


@dataclass
class KernelProfile:
    batch: int
    heads: int
    seq: int
    d_head: int
    dtype_bytes: int = 4

    # ---------------------------------------------------------- footprint

    def tile_bytes(self) -> dict:
        """Per-grid-step VMEM residency, by buffer."""
        s, d, b = self.seq, self.d_head, self.dtype_bytes
        return {
            "q": s * d * b,
            "k": s * d * b,
            "v": s * d * b,
            "scores": s * s * 4,  # f32 accumulator
            "out": s * d * b,
        }

    def vmem_per_step(self) -> int:
        return sum(self.tile_bytes().values())

    def vmem_fraction(self) -> float:
        return self.vmem_per_step() / VMEM_BYTES

    def fits_vmem(self) -> bool:
        # double-buffered inputs still need to fit
        return 2 * self.vmem_per_step() <= VMEM_BYTES

    # -------------------------------------------------------------- flops

    def matmul_flops_per_step(self) -> int:
        """MXU-issued FLOPs: qk^T and pv, 2*S*S*D each."""
        s, d = self.seq, self.d_head
        return 2 * (2 * s * s * d)

    def vpu_flops_per_step(self) -> int:
        """Elementwise softmax work (mask, max, exp, div): ~5 ops per score."""
        s = self.seq
        return 5 * s * s

    def mxu_fraction(self) -> float:
        m = self.matmul_flops_per_step()
        return m / (m + self.vpu_flops_per_step())

    # ----------------------------------------------------------- roofline

    def hbm_bytes_per_step(self) -> int:
        """HBM traffic: q, k, v in; out back. Scores never leave VMEM."""
        s, d, b = self.seq, self.d_head, self.dtype_bytes
        return 4 * s * d * b

    def arithmetic_intensity(self) -> float:
        return (self.matmul_flops_per_step() + self.vpu_flops_per_step()) / self.hbm_bytes_per_step()

    def compute_bound(self) -> bool:
        return self.arithmetic_intensity() >= MXU_RIDGE_FLOP_PER_BYTE

    def grid_steps(self) -> int:
        return self.batch * self.heads

    def report(self) -> dict:
        return {
            "grid_steps": self.grid_steps(),
            "vmem_per_step_kib": self.vmem_per_step() / 1024,
            "vmem_fraction": self.vmem_fraction(),
            "fits_vmem_double_buffered": self.fits_vmem(),
            "mxu_fraction": self.mxu_fraction(),
            "arithmetic_intensity": self.arithmetic_intensity(),
            "compute_bound": self.compute_bound(),
        }


def profile_preset(name: str, seq: int | None = None) -> KernelProfile:
    from ..configs import get

    cfg = get(name)
    return KernelProfile(
        batch=cfg.batch,
        heads=cfg.n_heads,
        seq=seq or cfg.seq_train,
        d_head=cfg.d_head,
    )


def main() -> None:
    rows = []
    for preset in ("path", "large"):
        for which in ("train", "eval"):
            from ..configs import get

            cfg = get(preset)
            seq = cfg.seq_train if which == "train" else cfg.seq_eval
            p = profile_preset(preset, seq)
            r = p.report()
            rows.append((f"{preset}/{which} (S={seq}, Dh={p.d_head})", r))
    # paper-scale reference: what the same schedule means at 150M scale
    paper = KernelProfile(batch=512, heads=16, seq=1024, d_head=64, dtype_bytes=2)
    rows.append(("paper-scale ref (S=1024, Dh=64, bf16)", paper.report()))

    hdr = f"{'kernel instance':<40} {'VMEM/step':>10} {'%VMEM':>7} {'MXU%':>6} {'AI':>7} {'bound':>8}"
    print(hdr)
    print("-" * len(hdr))
    for name, r in rows:
        print(
            f"{name:<40} {r['vmem_per_step_kib']:>8.1f}Ki {r['vmem_fraction']*100:>6.2f}% "
            f"{r['mxu_fraction']*100:>5.1f}% {r['arithmetic_intensity']:>7.1f} "
            f"{'compute' if r['compute_bound'] else 'memory':>8}"
        )


if __name__ == "__main__":
    main()
