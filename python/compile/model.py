"""L2 — the DiPaCo path model: a decoder-only transformer LM over a FLAT
parameter vector, plus every entrypoint the rust coordinator executes.

Why flat: DiPaCo's whole point is slicing parameters into modules (levels x
experts) that are assembled per path and diffed per module for the outer
optimizer. Keeping theta as one f32[N] vector makes the rust side a pure
range-slicing exercise driven by `manifest.json` — no pytree plumbing ever
crosses the language boundary.

Entrypoints (AOT-lowered by aot.py, executed from rust/src/runtime):

  init(seed)                          -> theta
  train_step(theta, m, v, step, lr, tokens) -> (theta', m', v', loss)
  token_logprobs(theta, tokens)       -> logp[batch, seq-1]
  features(theta, prefix_tokens)      -> z[batch, d_model]

The inner optimizer (AdamW, paper Table 4) lives INSIDE train_step's HLO so
the rust hot loop is: build literals -> execute -> swap buffers. The cosine
learning-rate schedule is computed in rust and passed in as a scalar.
"""

import functools

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.attention import attention

# ---------------------------------------------------------------------------
# Flat parameter layout
# ---------------------------------------------------------------------------


def layout(cfg: ModelConfig):
    """Ordered (name, shape) leaves of the flat parameter vector.

    Naming contract with rust (`rust/src/params/manifest.rs`):
    `block{i}.` prefixes group leaves into per-block units; the DiPaCo
    topology maps contiguous block ranges to levels. `embed.*`, `final.*`
    and `head.*` form the "stem" group (level assignment configurable).
    """
    leaves = []
    d, f = cfg.d_model, cfg.d_ff
    leaves.append(("embed.tok", (cfg.vocab, d)))
    leaves.append(("embed.pos", (cfg.seq_eval, d)))
    for i in range(cfg.n_layers):
        p = f"block{i}."
        leaves += [
            (p + "ln1.scale", (d,)),
            (p + "ln1.bias", (d,)),
            (p + "attn.wq", (d, d)),
            (p + "attn.wk", (d, d)),
            (p + "attn.wv", (d, d)),
            (p + "attn.wo", (d, d)),
            (p + "ln2.scale", (d,)),
            (p + "ln2.bias", (d,)),
            (p + "mlp.w1", (d, f)),
            (p + "mlp.b1", (f,)),
            (p + "mlp.w2", (f, d)),
            (p + "mlp.b2", (d,)),
        ]
    leaves += [
        ("final.ln.scale", (d,)),
        ("final.ln.bias", (d,)),
        ("head.w", (d, cfg.vocab)),
    ]
    return leaves


def total_params(cfg: ModelConfig) -> int:
    n = 0
    for _, shape in layout(cfg):
        sz = 1
        for s in shape:
            sz *= s
        n += sz
    return n


def unflatten(theta, cfg: ModelConfig):
    """Flat f32[N] -> {name: array}; static slices, free after XLA fusion."""
    out, off = {}, 0
    for name, shape in layout(cfg):
        sz = 1
        for s in shape:
            sz *= s
        out[name] = jax.lax.slice(theta, (off,), (off + sz,)).reshape(shape)
        off += sz
    return out


def flatten(params, cfg: ModelConfig):
    return jnp.concatenate([params[n].reshape(-1) for n, _ in layout(cfg)])


def decay_mask(cfg: ModelConfig):
    """1.0 where AdamW weight decay applies (matrices), 0.0 elsewhere
    (biases, layer norms). Baked into train_step as a constant."""
    segs = []
    for name, shape in layout(cfg):
        sz = 1
        for s in shape:
            sz *= s
        on = len(shape) == 2 and ".ln" not in name
        segs.append(jnp.full((sz,), 1.0 if on else 0.0, jnp.float32))
    return jnp.concatenate(segs)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def _block(x, p, prefix, cfg: ModelConfig):
    """Pre-LN transformer block; attention runs the L1 Pallas kernel."""
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    y = _layer_norm(x, p[prefix + "ln1.scale"], p[prefix + "ln1.bias"])
    q = (y @ p[prefix + "attn.wq"]).reshape(b, s, h, dh)
    k = (y @ p[prefix + "attn.wk"]).reshape(b, s, h, dh)
    v = (y @ p[prefix + "attn.wv"]).reshape(b, s, h, dh)
    # fuse (batch, heads) for the kernel grid
    q = q.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    k = k.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    v = v.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    o = attention(q, k, v)
    o = o.reshape(b, h, s, dh).transpose(0, 2, 1, 3).reshape(b, s, d)
    x = x + o @ p[prefix + "attn.wo"]
    y = _layer_norm(x, p[prefix + "ln2.scale"], p[prefix + "ln2.bias"])
    y = jax.nn.gelu(y @ p[prefix + "mlp.w1"] + p[prefix + "mlp.b1"])
    return x + y @ p[prefix + "mlp.w2"] + p[prefix + "mlp.b2"]


def hidden_states(theta, tokens, cfg: ModelConfig):
    """Final-block hidden states (pre final-LN), shape (b, s, d)."""
    p = unflatten(theta, cfg)
    b, s = tokens.shape
    x = p["embed.tok"][tokens] + p["embed.pos"][:s][None, :, :]
    for i in range(cfg.n_layers):
        x = _block(x, p, f"block{i}.", cfg)
    return x


def logits_fn(theta, tokens, cfg: ModelConfig):
    p = unflatten(theta, cfg)
    x = hidden_states(theta, tokens, cfg)
    x = _layer_norm(x, p["final.ln.scale"], p["final.ln.bias"])
    return x @ p["head.w"]


def token_logprobs(theta, tokens, cfg: ModelConfig):
    """logp[b, j] = log p(tokens[b, j+1] | tokens[b, :j+1]), j in [0, s-2].

    The rust side applies the prefix mask (paper §2.4: PPL over all but the
    first 32 tokens), chunk aggregation for eval-time re-routing (§2.4.3),
    and per-path scoring for the discriminative router (§2.4.2) — all from
    this one entrypoint.
    """
    lg = logits_fn(theta, tokens, cfg)[:, :-1, :]
    lp = jax.nn.log_softmax(lg.astype(jnp.float32), axis=-1)
    tgt = tokens[:, 1:]
    return jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]


def loss_fn(theta, tokens, cfg: ModelConfig):
    """Mean NLL over positions whose TARGET index >= cfg.prefix."""
    lp = token_logprobs(theta, tokens, cfg)  # (b, s-1), target idx j+1
    s = tokens.shape[1]
    tgt_idx = jnp.arange(1, s)
    mask = (tgt_idx >= cfg.prefix).astype(jnp.float32)[None, :]
    return -jnp.sum(lp * mask) / jnp.sum(mask * jnp.ones_like(lp))


def features(theta, prefix_tokens, cfg: ModelConfig):
    """Router feature z: mean final-block hidden state over the prefix
    (paper §7.2.1: "average of the hidden state from the last transformer
    block from the initial LM over the first 32 tokens")."""
    h = hidden_states(theta, prefix_tokens, cfg)
    return jnp.mean(h, axis=1)


# ---------------------------------------------------------------------------
# Training step (AdamW inside the HLO)
# ---------------------------------------------------------------------------


def train_step(theta, m, v, step, lr, tokens, cfg: ModelConfig):
    """One AdamW step on one batch. `step` is the 1-based step counter
    (f32 scalar, for bias correction); `lr` the schedule value from rust."""
    loss, g = jax.value_and_grad(loss_fn)(theta, tokens, cfg)
    b1, b2, eps = cfg.adam_b1, cfg.adam_b2, cfg.adam_eps
    m = b1 * m + (1.0 - b1) * g
    v = b2 * v + (1.0 - b2) * g * g
    mhat = m / (1.0 - b1 ** step)
    vhat = v / (1.0 - b2 ** step)
    update = mhat / (jnp.sqrt(vhat) + eps) + cfg.weight_decay * decay_mask(cfg) * theta
    return theta - lr * update, m, v, loss


def train_steps(theta, m, v, start_step, lrs, tokens, cfg: ModelConfig):
    """`cfg.tau` fused AdamW steps via lax.scan (§Perf optimization: one
    PJRT dispatch + one host<->device parameter round trip per chunk
    instead of per step).

    Args:
      start_step: f32 scalar, 0-based global step before this chunk.
      lrs: f32[tau] schedule values.
      tokens: int32[tau, batch, seq_train].
    Returns: (theta', m', v', losses[tau]).
    """

    def body(carry, xs):
        theta, m, v, step = carry
        lr, toks = xs
        step = step + 1.0
        theta, m, v, loss = train_step(theta, m, v, step, lr, toks, cfg)
        return (theta, m, v, step), loss

    (theta, m, v, _), losses = jax.lax.scan(
        body, (theta, m, v, start_step), (lrs, tokens)
    )
    return theta, m, v, losses


def grad_step(theta, tokens, cfg: ModelConfig):
    """Loss and raw gradient — used by the fully-synchronous ablation
    (paper §4.5), where rust aggregates gradients across paths module-by-
    module before a single shared AdamW update."""
    loss, g = jax.value_and_grad(loss_fn)(theta, tokens, cfg)
    return g, loss


def adam_update(theta, m, v, g, step, lr, cfg: ModelConfig):
    """AdamW update from a PRE-AGGREGATED gradient (sync ablation)."""
    b1, b2, eps = cfg.adam_b1, cfg.adam_b2, cfg.adam_eps
    m = b1 * m + (1.0 - b1) * g
    v = b2 * v + (1.0 - b2) * g * g
    mhat = m / (1.0 - b1 ** step)
    vhat = v / (1.0 - b2 ** step)
    update = mhat / (jnp.sqrt(vhat) + eps) + cfg.weight_decay * decay_mask(cfg) * theta
    return theta - lr * update, m, v


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init(seed, cfg: ModelConfig):
    """GPT-2-style init from a uint32 seed scalar: N(0, 0.02) matrices with
    1/sqrt(2*n_layers) scaling on residual-output projections; zeros for
    biases; ones for LN scales."""
    key = jax.random.PRNGKey(seed)
    segs = []
    resid_scale = 1.0 / (2.0 * cfg.n_layers) ** 0.5
    for name, shape in layout(cfg):
        key, sub = jax.random.split(key)
        sz = 1
        for s in shape:
            sz *= s
        if name.endswith("ln.scale") or ".ln1.scale" in name or ".ln2.scale" in name:
            segs.append(jnp.ones((sz,), jnp.float32))
        elif len(shape) == 1:
            segs.append(jnp.zeros((sz,), jnp.float32))
        else:
            w = jax.random.normal(sub, (sz,), jnp.float32) * 0.02
            if name.endswith("attn.wo") or name.endswith("mlp.w2"):
                w = w * resid_scale
            segs.append(w)
    return jnp.concatenate(segs)


# ---------------------------------------------------------------------------
# Entrypoint table for AOT lowering
# ---------------------------------------------------------------------------


def entrypoints(cfg: ModelConfig):
    """name -> (fn, example_args). Lowered to HLO text by aot.py."""
    n = total_params(cfg)
    f32 = jnp.float32
    vec = jax.ShapeDtypeStruct((n,), f32)
    scalar = jax.ShapeDtypeStruct((), f32)
    seed = jax.ShapeDtypeStruct((), jnp.uint32)
    tok_tr = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_train), jnp.int32)
    tok_ev = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_eval), jnp.int32)
    tok_px = jax.ShapeDtypeStruct((cfg.batch, cfg.prefix), jnp.int32)

    def ep(fn):
        return functools.partial(fn, cfg=cfg)

    tok_scan = jax.ShapeDtypeStruct((cfg.tau, cfg.batch, cfg.seq_train), jnp.int32)
    lrs = jax.ShapeDtypeStruct((cfg.tau,), f32)

    return {
        "init": (ep(init), (seed,)),
        "train_step": (ep(train_step), (vec, vec, vec, scalar, scalar, tok_tr)),
        "train_steps": (ep(train_steps), (vec, vec, vec, scalar, lrs, tok_scan)),
        "grad_step": (ep(grad_step), (vec, tok_tr)),
        "adam_update": (ep(adam_update), (vec, vec, vec, vec, scalar, scalar)),
        "token_logprobs_train": (ep(token_logprobs), (vec, tok_tr)),
        "token_logprobs_eval": (ep(token_logprobs), (vec, tok_ev)),
        "features": (ep(features), (vec, tok_px)),
    }
