"""AOT compile path: lower every L2 entrypoint to HLO TEXT + manifest.

HLO *text* (not `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids which the image's xla_extension
0.5.1 (behind the rust `xla` crate) rejects (`proto.id() <= INT_MAX`); the
text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md and gen_hlo.py there.

Usage (from python/):  python -m compile.aot --preset path --out ../artifacts

Outputs artifacts/<preset>/:
  {init,train_step,grad_step,adam_update,token_logprobs_train,
   token_logprobs_eval,features}.hlo.txt
  manifest.json   — flat-parameter layout + resolved model config; the
                    rust side treats this as the source of truth.
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import configs, model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_manifest(cfg: configs.ModelConfig) -> dict:
    leaves, off = [], 0
    for name, shape in model.layout(cfg):
        sz = 1
        for s in shape:
            sz *= s
        leaves.append(
            {"name": name, "offset": off, "size": sz, "shape": list(shape)}
        )
        off += sz
    return {
        "preset": cfg.name,
        "config": cfg.to_dict(),
        "total_params": off,
        "leaves": leaves,
        "entrypoints": sorted(model.entrypoints(cfg).keys()),
    }


def lower_preset(preset: str, out_root: str, only=None) -> str:
    cfg = configs.get(preset)
    out_dir = os.path.join(out_root, preset)
    os.makedirs(out_dir, exist_ok=True)
    eps = model.entrypoints(cfg)
    for name, (fn, example_args) in eps.items():
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"  {path}  ({len(text)/1e6:.2f} MB)")
    manifest = build_manifest(cfg)
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  {mpath}  (total_params={manifest['total_params']})")
    return mpath


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", required=True, choices=sorted(configs.PRESETS))
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", nargs="*", help="subset of entrypoints")
    args = ap.parse_args()
    print(f"[aot] lowering preset={args.preset}")
    lower_preset(args.preset, args.out, only=args.only)


if __name__ == "__main__":
    main()
